//! Kronecker-structured ridge system for the attention logit compensator
//! (App. B.2).
//!
//! Per layer and head, CORP accumulates over calibration samples b:
//!
//!   G += (K_S,bᵀ K_S,b) ⊗ (Q_S,bᵀ Q_S,b)            ∈ R^{d'² × d'²}
//!   h += vec( (Q_S,bᵀ Q_P,b)(K_P,bᵀ K_S,b) )        ∈ R^{d'²}
//!
//! then solves (G + λI) vec(M) = h. vec(·) is **column-major** (the
//! convention under which vec(AMBᵀ) = (B ⊗ A) vec(M) holds).

use super::chol::Cholesky;
use super::Mat;

/// Accumulator for the per-head Kronecker ridge system.
pub struct KronRidge {
    /// Kept dimension d'_h.
    pub d: usize,
    /// Gram tensor G, [d'², d'²].
    pub g: Mat,
    /// Right-hand side h, length d'².
    pub h: Vec<f64>,
    /// Running uncompensated energy Σ_b ‖T_b‖²_F (for the exact distortion
    /// identity of Prop. C.2.1 — available "at no additional cost").
    pub t_energy: f64,
    /// Number of accumulated samples.
    pub count: usize,
}

impl KronRidge {
    pub fn new(d: usize) -> Self {
        Self { d, g: Mat::zeros(d * d, d * d), h: vec![0.0; d * d], t_energy: 0.0, count: 0 }
    }

    /// Accumulate one calibration sample's contribution.
    ///
    /// `kk` = K_Sᵀ K_S [d,d], `qq` = Q_Sᵀ Q_S [d,d],
    /// `r`  = (Q_Sᵀ Q_P)(K_Pᵀ K_S) [d,d],
    /// `t_sq` = ‖Q_P K_Pᵀ‖²_F for this sample.
    pub fn accumulate(&mut self, kk: &Mat, qq: &Mat, r: &Mat, t_sq: f64) {
        let d = self.d;
        assert_eq!((kk.r, kk.c, qq.r, qq.c, r.r, r.c), (d, d, d, d, d, d));
        let n = d * d;
        // G[(j*d + i), (l*d + k)] += KK[j,l] * QQ[i,k]   (column-major vec)
        for j in 0..d {
            for l in 0..d {
                let s = kk.at(j, l);
                if s == 0.0 {
                    continue;
                }
                // dense block add: rows j*d..j*d+d, cols l*d..l*d+d
                for i in 0..d {
                    let grow = &mut self.g.a[(j * d + i) * n + l * d..(j * d + i) * n + l * d + d];
                    let qrow = &qq.a[i * d..(i + 1) * d];
                    for k in 0..d {
                        grow[k] += s * qrow[k];
                    }
                }
            }
        }
        // h[j*d + i] += R[i, j]
        for j in 0..d {
            for i in 0..d {
                self.h[j * d + i] += r.at(i, j);
            }
        }
        self.t_energy += t_sq;
        self.count += 1;
    }

    /// Solve (G + λ·scale·I) vec(M) = h and return M [d, d].
    /// λ is normalized by the mean diagonal of G, as in `ridge_right`.
    pub fn solve(&self, lambda: f64) -> Mat {
        let d = self.d;
        let n = d * d;
        let scale = (self.g.trace() / n as f64).max(1e-12);
        let reg = self.g.add_diag(lambda * scale);
        let (f, _) = Cholesky::new_with_jitter(&reg);
        let m_vec = f.solve_vec(&self.h);
        let mut m = Mat::zeros(d, d);
        for j in 0..d {
            for i in 0..d {
                m.set(i, j, m_vec[j * d + i]);
            }
        }
        m
    }

    /// Exact compensated distortion J_D(M) = Σ‖T_b‖² − 2 hᵀm + mᵀG m
    /// (Prop. C.2.1 Eq. 81 without the regularizer term).
    pub fn distortion(&self, m: &Mat) -> f64 {
        let d = self.d;
        let n = d * d;
        let mut mv = vec![0.0; n];
        for j in 0..d {
            for i in 0..d {
                mv[j * d + i] = m.at(i, j);
            }
        }
        let mut gm = vec![0.0; n];
        for i in 0..n {
            let row = &self.g.a[i * n..(i + 1) * n];
            gm[i] = row.iter().zip(&mv).map(|(a, b)| a * b).sum();
        }
        let h_m: f64 = self.h.iter().zip(&mv).map(|(a, b)| a * b).sum();
        let m_gm: f64 = mv.iter().zip(&gm).map(|(a, b)| a * b).sum();
        self.t_energy - 2.0 * h_m + m_gm
    }

    /// Compensation gain hᵀ (G+λI)⁻¹ h ≥ 0 (Prop. C.2.2 with ridge), and the
    /// bilinear coefficient of determination ρ²_attn = gain / Σ‖T_b‖².
    pub fn gain_and_rho2(&self, lambda: f64) -> (f64, f64) {
        let m = self.solve(lambda);
        let j_comp = self.distortion(&m);
        let gain = (self.t_energy - j_comp).max(0.0);
        let rho2 = if self.t_energy > 0.0 { (gain / self.t_energy).clamp(0.0, 1.0) } else { 0.0 };
        (gain, rho2)
    }
}

/// Dense Kronecker product B ⊗ A (test/diagnostic helper; the accumulator
/// above never materializes per-sample Kroneckers separately).
pub fn kron(b: &Mat, a: &Mat) -> Mat {
    let mut out = Mat::zeros(b.r * a.r, b.c * a.c);
    for i in 0..b.r {
        for j in 0..b.c {
            let s = b.at(i, j);
            for p in 0..a.r {
                for q in 0..a.c {
                    out.set(i * a.r + p, j * a.c + q, s * a.at(p, q));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};

    /// Reference: build T = Q_P K_Pᵀ approximation objective directly and
    /// verify the normal-equation solution matches a brute-force vec solve.
    #[test]
    fn kron_identity_vec_amb() {
        run_prop("kron.vec(AMB^T)=(B⊗A)vec(M)", 15, |rng| {
            let d = gen::dim(rng, 1, 4);
            let n_tok = gen::dim(rng, 2, 6);
            let a = Mat::from_f32(n_tok, d, &gen::matrix(rng, n_tok, d, 1.0));
            let b = Mat::from_f32(n_tok, d, &gen::matrix(rng, n_tok, d, 1.0));
            let m = Mat::from_f32(d, d, &gen::matrix(rng, d, d, 1.0));
            let lhs = a.mul(&m).mul(&b.t()); // [n_tok, n_tok]
            // rhs: (B ⊗ A) vec(M), column-major vecs
            let kab = kron(&b, &a);
            let mut mv = vec![0.0; d * d];
            for j in 0..d {
                for i in 0..d {
                    mv[j * d + i] = m.at(i, j);
                }
            }
            let mut out = vec![0.0; n_tok * n_tok];
            for i in 0..n_tok * n_tok {
                out[i] = kab.row(i).iter().zip(&mv).map(|(x, y)| x * y).sum();
            }
            // compare: vec_cm(lhs)[j*n + i] = lhs[i, j]
            for j in 0..n_tok {
                for i in 0..n_tok {
                    assert!((out[j * n_tok + i] - lhs.at(i, j)).abs() < 1e-8);
                }
            }
        });
    }

    #[test]
    fn solve_recovers_planted_m() {
        // If T_b = Q_S M* K_Sᵀ exactly, the solver must recover M* (λ→0).
        run_prop("kron.recovers planted M", 10, |rng| {
            let d = gen::dim(rng, 1, 4);
            let m_true = Mat::from_f32(d, d, &gen::matrix(rng, d, d, 1.0));
            let mut acc = KronRidge::new(d);
            for _ in 0..6 {
                let n_tok = 8;
                let qs = Mat::from_f32(n_tok, d, &gen::matrix(rng, n_tok, d, 1.0));
                let ks = Mat::from_f32(n_tok, d, &gen::matrix(rng, n_tok, d, 1.0));
                let t = qs.mul(&m_true).mul(&ks.t());
                let kk = ks.t().mul(&ks);
                let qq = qs.t().mul(&qs);
                // r = Q_Sᵀ T K_S
                let r = qs.t().mul(&t).mul(&ks);
                acc.accumulate(&kk, &qq, &r, t.frob().powi(2));
            }
            let m = acc.solve(1e-9);
            assert!(m.max_abs_diff(&m_true) < 1e-4, "d={d}");
        });
    }

    #[test]
    fn distortion_matches_direct_objective() {
        run_prop("kron.distortion identity", 10, |rng| {
            let d = gen::dim(rng, 1, 3);
            let dp = gen::dim(rng, 1, 3); // pruned dim
            let mut acc = KronRidge::new(d);
            let mut samples = Vec::new();
            for _ in 0..4 {
                let n_tok = 6;
                let qs = Mat::from_f32(n_tok, d, &gen::matrix(rng, n_tok, d, 1.0));
                let ks = Mat::from_f32(n_tok, d, &gen::matrix(rng, n_tok, d, 1.0));
                let qp = Mat::from_f32(n_tok, dp, &gen::matrix(rng, n_tok, dp, 1.0));
                let kp = Mat::from_f32(n_tok, dp, &gen::matrix(rng, n_tok, dp, 1.0));
                let t = qp.mul(&kp.t());
                let kk = ks.t().mul(&ks);
                let qq = qs.t().mul(&qs);
                let r = qs.t().mul(&qp).mul(&kp.t().mul(&ks));
                acc.accumulate(&kk, &qq, &r, t.frob().powi(2));
                samples.push((qs, ks, t));
            }
            let m = acc.solve(1e-3);
            // direct objective
            let direct: f64 = samples
                .iter()
                .map(|(qs, ks, t)| {
                    let approx = qs.mul(&m).mul(&ks.t());
                    t.sub(&approx).frob().powi(2)
                })
                .sum();
            let viaformula = acc.distortion(&m);
            assert!((direct - viaformula).abs() < 1e-6 * (1.0 + direct), "{direct} vs {viaformula}");
        });
    }

    #[test]
    fn gain_nonnegative_and_rho_bounded() {
        run_prop("kron.gain >= 0, rho2 in [0,1]", 10, |rng| {
            let d = gen::dim(rng, 1, 3);
            let mut acc = KronRidge::new(d);
            for _ in 0..3 {
                let n_tok = 5;
                let qs = Mat::from_f32(n_tok, d, &gen::matrix(rng, n_tok, d, 1.0));
                let ks = Mat::from_f32(n_tok, d, &gen::matrix(rng, n_tok, d, 1.0));
                let qp = Mat::from_f32(n_tok, 2, &gen::matrix(rng, n_tok, 2, 1.0));
                let kp = Mat::from_f32(n_tok, 2, &gen::matrix(rng, n_tok, 2, 1.0));
                let t = qp.mul(&kp.t());
                acc.accumulate(
                    &ks.t().mul(&ks),
                    &qs.t().mul(&qs),
                    &qs.t().mul(&qp).mul(&kp.t().mul(&ks)),
                    t.frob().powi(2),
                );
            }
            let (gain, rho2) = acc.gain_and_rho2(1e-6);
            assert!(gain >= 0.0);
            assert!((0.0..=1.0).contains(&rho2));
        });
    }

    #[test]
    fn g_matches_dense_kron_sum() {
        let mut rng = crate::util::Pcg64::new(77);
        let d = 3;
        let mut acc = KronRidge::new(d);
        let mut dense = Mat::zeros(d * d, d * d);
        for _ in 0..3 {
            let n_tok = 5;
            let qs = Mat::from_f32(n_tok, d, &gen::matrix(&mut rng, n_tok, d, 1.0));
            let ks = Mat::from_f32(n_tok, d, &gen::matrix(&mut rng, n_tok, d, 1.0));
            let kk = ks.t().mul(&ks);
            let qq = qs.t().mul(&qs);
            dense = dense.add(&kron(&kk, &qq));
            acc.accumulate(&kk, &qq, &Mat::zeros(d, d), 0.0);
        }
        assert!(acc.g.max_abs_diff(&dense) < 1e-9);
    }
}
