//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Used for: pseudo-inverses of possibly-singular covariance blocks
//! (distortion diagnostics), effective-rank / k95 statistics (Table 9), and
//! as the backend of the small SVDs that fold `I + M` into the Q/K
//! projections (Alg. 5).

use super::Mat;

/// Eigendecomposition of a symmetric matrix: returns (eigenvalues, V) with
/// A = V diag(vals) Vᵀ. Eigenvalues are sorted descending; V's columns are
/// the corresponding orthonormal eigenvectors.
pub fn sym_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.r, a.c);
    let n = a.r;
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.at(i, j) * m.at(i, j);
            }
        }
        let scale = m.frob().max(1e-300);
        if off.sqrt() <= 1e-14 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,θ) on both sides of m and on v.
                for k in 0..n {
                    let mkp = m.at(k, p);
                    let mkq = m.at(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.at(p, k);
                    let mqk = m.at(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let mut vals: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
    // Sort descending, permuting V's columns accordingly.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| vals[j].total_cmp(&vals[i]));
    let sorted_vals: Vec<f64> = order.iter().map(|&i| vals[i]).collect();
    let mut sorted_v = Mat::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            sorted_v.set(r, new_c, v.at(r, old_c));
        }
    }
    vals = sorted_vals;
    (vals, sorted_v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_rows(3, 3, vec![3., 0., 0., 0., 1., 0., 0., 0., 2.]);
        let (vals, _) = sym_eig(&a);
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_prop() {
        run_prop("eig.A = V D V^T", 15, |rng| {
            let n = gen::dim(rng, 1, 12);
            let mut a = Mat::from_f32(n, n, &gen::matrix(rng, n, n, 1.0));
            a.symmetrize();
            let (vals, v) = sym_eig(&a);
            // rebuild
            let mut d = Mat::zeros(n, n);
            for i in 0..n {
                d.set(i, i, vals[i]);
            }
            let rebuilt = v.mul(&d).mul(&v.t());
            assert!(rebuilt.max_abs_diff(&a) < 1e-8 * (1.0 + a.max_abs()), "n={n}");
        });
    }

    #[test]
    fn eigenvectors_orthonormal_prop() {
        run_prop("eig.V^T V = I", 15, |rng| {
            let n = gen::dim(rng, 1, 12);
            let mut a = Mat::from_f32(n, n, &gen::matrix(rng, n, n, 1.0));
            a.symmetrize();
            let (_, v) = sym_eig(&a);
            assert!(v.t().mul(&v).max_abs_diff(&Mat::eye(n)) < 1e-9);
        });
    }

    #[test]
    fn psd_eigenvalues_nonnegative() {
        run_prop("eig.PSD => vals >= 0", 10, |rng| {
            let n = gen::dim(rng, 2, 10);
            let a = Mat::from_f32(n, n, &gen::spd(rng, n, 0.0));
            let (vals, _) = sym_eig(&a);
            for v in vals {
                assert!(v > -1e-8, "negative eigenvalue {v}");
            }
        });
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3 and 1.
        let a = Mat::from_rows(2, 2, vec![2., 1., 1., 2.]);
        let (vals, v) = sym_eig(&a);
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 1.0).abs() < 1e-12);
        // eigenvector for 3 is [1,1]/sqrt2 up to sign
        let e = (v.at(0, 0) * v.at(1, 0)).signum();
        assert!(e > 0.0);
    }
}
