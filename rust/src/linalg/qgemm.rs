//! Int8 weight-quantized GEMM for the serving forward path.
//!
//! Weights are quantized **per output channel** (symmetric, round to
//! nearest, clamp to ±127): channel j stores `q[i] = round(w[i][j] / s_j)`
//! with `s_j = max_i |w[i][j]| / 127`, laid out channel-major (`[dout,
//! din]` row-major) so each output channel's weights are one contiguous
//! i8 run. Activations are quantized **per row, dynamically** at dispatch
//! time with the same symmetric rule. The kernel accumulates the i8×i8
//! products in i32 — *exact* integer arithmetic, so the AVX2 path
//! (`_mm256_madd_epi16` over sign-extended 16-lane chunks) and the scalar
//! multi-accumulator produce identical sums in any order — and applies one
//! f32 dequant epilogue per output: `out += x_scale · s_j · acc`.
//!
//! Two consequences the serving stack leans on:
//!
//! * **Determinism** — quantization, the integer dot, and the epilogue are
//!   all order-insensitive or fixed-order, so int8 predictions are
//!   invariant to worker count, dispatch policy, and SIMD dispatch (the
//!   same guarantee the f32 kernels give, tested bitwise).
//! * **Correctable error** — the f32→int8 output residual of a channel is
//!   an affine function of that channel's exact output on any fixed input
//!   distribution, which is why `compensate::quant` can fit it in closed
//!   form from the calibration Gram accumulators and fold the fix into
//!   `s_j` and the bias (see `compensate/quant.rs`).
//!
//! Dispatch reuses [`super::gemm::simd_enabled`] (`CORP_SIMD=off` forces
//! the scalar path); parallelism reuses the worker pool with the same
//! row-ownership scheme as the f32 kernels.

use super::gemm::simd_enabled;
use crate::util::threads;

/// Rows of the output per parallel work unit.
const RB: usize = 16;

/// A per-output-channel symmetric int8 quantized weight matrix for a
/// linear layer `y = x · W` with `W` logically `[din, dout]`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantMat {
    /// Channel-major quantized weights: `data[j * din + i]` is channel j's
    /// weight for input i.
    pub data: Vec<i8>,
    /// Per-output-channel dequant scales (`s_j`); zero for all-zero
    /// channels.
    pub scales: Vec<f32>,
    pub din: usize,
    pub dout: usize,
}

impl QuantMat {
    /// In-memory footprint of the quantized payload in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }
}

/// Quantize a row-major `[din, dout]` f32 weight matrix per output
/// channel: `s_j = max_i |w[i][j]| / 127`, `q = round(w / s_j)` clamped to
/// ±127 (`f32::round` — half away from zero). All-zero channels store
/// `s_j = 0` and zero codes.
pub fn quantize(w: &[f32], din: usize, dout: usize) -> QuantMat {
    assert_eq!(w.len(), din * dout);
    let mut data = vec![0i8; din * dout];
    let mut scales = vec![0.0f32; dout];
    for j in 0..dout {
        let mut amax = 0.0f32;
        for i in 0..din {
            amax = amax.max(w[i * dout + j].abs());
        }
        if amax == 0.0 {
            continue; // scale 0, codes 0
        }
        let scale = amax / 127.0;
        let inv = 127.0 / amax;
        scales[j] = scale;
        let chan = &mut data[j * din..(j + 1) * din];
        for (i, q) in chan.iter_mut().enumerate() {
            *q = (w[i * dout + j] * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }
    QuantMat { data, scales, din, dout }
}

/// Reconstruct the row-major `[din, dout]` f32 matrix `q · s_j` — the
/// matrix the int8 kernel effectively multiplies by (up to activation
/// quantization). Used by the round-trip tests and the dequant-correction
/// fit.
pub fn dequant(qm: &QuantMat) -> Vec<f32> {
    let mut out = vec![0.0f32; qm.din * qm.dout];
    for j in 0..qm.dout {
        let s = qm.scales[j];
        let chan = &qm.data[j * qm.din..(j + 1) * qm.din];
        for (i, &q) in chan.iter().enumerate() {
            out[i * qm.dout + j] = q as f32 * s;
        }
    }
    out
}

/// Symmetric per-row activation quantization: returns the row's codes in
/// `xq` and its dequant scale (`max|x| / 127`; zero rows get scale 0).
#[inline]
fn quantize_row(x: &[f32], xq: &mut [i8]) -> f32 {
    let mut amax = 0.0f32;
    for &v in x {
        amax = amax.max(v.abs());
    }
    if amax == 0.0 {
        xq.fill(0);
        return 0.0;
    }
    let inv = 127.0 / amax;
    for (q, &v) in xq.iter_mut().zip(x) {
        *q = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    amax / 127.0
}

/// out[rows, dout] += x[rows, din] · W where W is the int8 matrix `qm`
/// stands for. Per-row dynamic activation quantization, i32 accumulation,
/// f32 dequant epilogue. Same accumulate-into-C semantics and row-panel
/// parallelism as [`super::gemm::matmul_f32`].
pub fn matmul_q8(x: &[f32], qm: &QuantMat, out: &mut [f32], rows: usize) {
    matmul_q8_raw(x, &qm.data, &qm.scales, qm.din, qm.dout, out, rows);
}

/// [`matmul_q8`] over borrowed code/scale slices (channel-major codes as in
/// [`QuantMat`]) — the runtime's `Input::Q8` path, where the quantized
/// weight is a view into a store rather than an owned matrix.
pub fn matmul_q8_raw(
    x: &[f32],
    data: &[i8],
    scales: &[f32],
    din: usize,
    dout: usize,
    out: &mut [f32],
    rows: usize,
) {
    assert_eq!(x.len(), rows * din);
    assert_eq!(data.len(), din * dout);
    assert_eq!(scales.len(), dout);
    assert_eq!(out.len(), rows * dout);
    if rows == 0 || dout == 0 || din == 0 {
        return;
    }
    let simd = simd_enabled();
    threads::parallel_chunks_mut(out, RB * dout, |panel, opan| {
        let r0 = panel * RB;
        let pr = opan.len() / dout;
        let mut xq = vec![0i8; din];
        for r in 0..pr {
            let xrow = &x[(r0 + r) * din..(r0 + r + 1) * din];
            let xs = quantize_row(xrow, &mut xq);
            let orow = &mut opan[r * dout..(r + 1) * dout];
            if xs == 0.0 {
                continue; // zero row contributes nothing
            }
            for (j, ov) in orow.iter_mut().enumerate() {
                let ws = scales[j];
                if ws == 0.0 {
                    continue;
                }
                let chan = &data[j * din..(j + 1) * din];
                let acc = dot_i8_dispatch(&xq, chan, simd);
                *ov += xs * ws * acc as f32;
            }
        }
    });
}

#[inline]
fn dot_i8_dispatch(a: &[i8], b: &[i8], simd: bool) -> i32 {
    #[cfg(target_arch = "x86_64")]
    if simd {
        // Safety: `simd` is only true when the AVX2 probe succeeded.
        return unsafe { dot_i8_avx2(a, b) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = simd;
    dot_i8(a, b)
}

/// Scalar i8·i8 → i32 dot with an 8-lane multi-accumulator (integer adds
/// are associative, so LLVM is free to vectorize this; the explicit AVX2
/// path below is exactly equal by integer exactness).
#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0i32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let av = &a[i * 8..(i + 1) * 8];
        let bv = &b[i * 8..(i + 1) * 8];
        for j in 0..8 {
            acc[j] += av[j] as i32 * bv[j] as i32;
        }
    }
    let mut s: i32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        s += a[i] as i32 * b[i] as i32;
    }
    s
}

/// AVX2 i8 dot: sign-extend 16 codes a side to i16, `madd` the pairs into
/// 8 i32 lanes, accumulate. Products are ≤ 127² and the depth of any layer
/// here is ≪ 2³¹/127²/2, so the i32 lanes cannot overflow; the result is
/// exactly the scalar sum.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 16;
    let mut vacc = _mm256_setzero_si256();
    for i in 0..chunks {
        let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i * 16) as *const __m128i));
        let bv = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(i * 16) as *const __m128i));
        vacc = _mm256_add_epi32(vacc, _mm256_madd_epi16(av, bv));
    }
    let mut lanes = [0i32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, vacc);
    let mut s: i32 = lanes.iter().sum();
    for i in chunks * 16..a.len() {
        s += a[i] as i32 * b[i] as i32;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};

    fn naive_f64(x: &[f32], w: &[f32], rows: usize, din: usize, dout: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; rows * dout];
        for r in 0..rows {
            for j in 0..dout {
                out[r * dout + j] = (0..din)
                    .map(|i| x[r * din + i] as f64 * w[i * dout + j] as f64)
                    .sum();
            }
        }
        out
    }

    /// Satellite: quantize→dequant round-trip error is bounded per entry by
    /// half a quantization step of its channel, and scales match the
    /// max-abs rule.
    #[test]
    fn quantize_dequant_roundtrip_bounds() {
        run_prop("qgemm.roundtrip bound", 20, |rng| {
            let (din, dout) = (gen::dim(rng, 1, 60), gen::dim(rng, 1, 40));
            let w = gen::matrix(rng, din, dout, 1.0);
            let qm = quantize(&w, din, dout);
            let dq = dequant(&qm);
            for j in 0..dout {
                let amax = (0..din).map(|i| w[i * dout + j].abs()).fold(0.0f32, f32::max);
                assert!(
                    (qm.scales[j] - amax / 127.0).abs() <= 1e-6 * (1.0 + amax),
                    "scale rule violated at j={j}"
                );
                for i in 0..din {
                    let err = (w[i * dout + j] - dq[i * dout + j]).abs();
                    assert!(
                        err <= 0.5 * qm.scales[j] + 1e-6,
                        "entry ({i},{j}) err {err} > step/2 {}",
                        0.5 * qm.scales[j]
                    );
                }
            }
        });
    }

    #[test]
    fn zero_channel_gets_zero_scale() {
        let din = 5;
        let mut w = vec![0.0f32; din * 3];
        for i in 0..din {
            w[i * 3] = (i as f32) - 2.0; // channel 0 nonzero
            // channel 1 all zero
            w[i * 3 + 2] = 1.0; // channel 2 constant
        }
        let qm = quantize(&w, din, 3);
        assert_eq!(qm.scales[1], 0.0);
        assert!(qm.data[din..2 * din].iter().all(|&q| q == 0));
        let dq = dequant(&qm);
        for i in 0..din {
            assert_eq!(dq[i * 3 + 1], 0.0);
        }
    }

    /// The kernel result differs from the exact f64 product by at most the
    /// analytic quantization bound: per (row r, channel j),
    /// |Δ| ≤ Σᵢ|xᵢ|·(s_j/2) + (xs/2)·Σᵢ|ŵᵢⱼ| + din·(xs/2)·(s_j/2).
    #[test]
    fn matmul_q8_within_analytic_bound() {
        run_prop("qgemm.analytic bound", 12, |rng| {
            let (rows, din, dout) =
                (gen::dim(rng, 1, 20), gen::dim(rng, 1, 80), gen::dim(rng, 1, 30));
            let x = gen::matrix(rng, rows, din, 1.0);
            let w = gen::matrix(rng, din, dout, 1.0);
            let qm = quantize(&w, din, dout);
            let dq = dequant(&qm);
            let mut out = vec![0.0f32; rows * dout];
            matmul_q8(&x, &qm, &mut out, rows);
            let want = naive_f64(&x, &w, rows, din, dout);
            for r in 0..rows {
                let xrow = &x[r * din..(r + 1) * din];
                let amax = xrow.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let xs = amax / 127.0;
                let sum_absx: f64 = xrow.iter().map(|v| v.abs() as f64).sum();
                for j in 0..dout {
                    let sj = qm.scales[j] as f64;
                    let sum_absw: f64 =
                        (0..din).map(|i| dq[i * dout + j].abs() as f64).sum();
                    let bound = sum_absx * sj * 0.5
                        + (xs as f64) * 0.5 * sum_absw
                        + din as f64 * (xs as f64) * 0.5 * sj * 0.5
                        + 1e-3;
                    let got = out[r * dout + j] as f64;
                    let err = (got - want[r * dout + j]).abs();
                    assert!(
                        err <= bound,
                        "({r},{j}) err {err} > bound {bound} (got {got}, want {})",
                        want[r * dout + j]
                    );
                }
            }
        });
    }

    /// Codes that need no rounding reproduce the f32 product exactly (up
    /// to the f32 epilogue): weights and activations on an exact grid.
    #[test]
    fn matmul_q8_exact_on_grid() {
        let (rows, din, dout) = (3usize, 16usize, 5usize);
        let mut rng = crate::util::Pcg64::new(11);
        let x: Vec<f32> = (0..rows * din).map(|_| (rng.below(255) as i64 - 127) as f32).collect();
        let w: Vec<f32> = (0..din * dout).map(|_| (rng.below(255) as i64 - 127) as f32).collect();
        let qm = quantize(&w, din, dout);
        let mut out = vec![0.0f32; rows * dout];
        matmul_q8(&x, &qm, &mut out, rows);
        let want = naive_f64(&x, &w, rows, din, dout);
        for (g, w) in out.iter().zip(&want) {
            // i32-exact accumulation; only the two-factor f32 epilogue
            // rounds, so the products agree to f32 precision.
            assert!(
                (*g as f64 - w).abs() <= 1e-2 * (1.0 + w.abs()),
                "{g} vs {w}"
            );
        }
    }

    /// SIMD dispatch does not change the int8 result at all (integer
    /// accumulation is exact in any order; the epilogue is identical).
    #[test]
    fn matmul_q8_simd_matches_scalar_bitwise() {
        use crate::linalg::gemm::force_simd;
        let mut rng = crate::util::Pcg64::new(21);
        for &(rows, din, dout) in
            &[(1usize, 1usize, 1usize), (2, 15, 9), (3, 16, 8), (4, 17, 33), (5, 130, 20)]
        {
            let x = gen::matrix(&mut rng, rows, din, 1.0);
            let w = gen::matrix(&mut rng, din, dout, 1.0);
            let qm = quantize(&w, din, dout);
            let mut o_simd = vec![0.0f32; rows * dout];
            force_simd(Some(true), || matmul_q8(&x, &qm, &mut o_simd, rows));
            let mut o_scal = vec![0.0f32; rows * dout];
            force_simd(Some(false), || matmul_q8(&x, &qm, &mut o_scal, rows));
            assert_eq!(
                o_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                o_scal.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "q8 simd!=scalar at rows={rows} din={din} dout={dout}"
            );
        }
    }

    #[test]
    fn accumulates_into_out() {
        let x = [1.0f32, 2.0];
        let w = [3.0f32, 4.0]; // [din=2, dout=1]
        let qm = quantize(&w, 2, 1);
        let mut out = vec![10.0f32];
        matmul_q8(&x, &qm, &mut out, 1);
        assert!((out[0] - 21.0).abs() < 0.1, "{}", out[0]);
    }

    #[test]
    fn worker_count_invariance() {
        use crate::util::threads::with_threads;
        let mut rng = crate::util::Pcg64::new(31);
        let (rows, din, dout) = (70usize, 64usize, 24usize);
        let x = gen::matrix(&mut rng, rows, din, 1.0);
        let w = gen::matrix(&mut rng, din, dout, 1.0);
        let qm = quantize(&w, din, dout);
        let mut o1 = vec![0.0f32; rows * dout];
        with_threads(1, || matmul_q8(&x, &qm, &mut o1, rows));
        for wkr in [2usize, 4] {
            let mut ow = vec![0.0f32; rows * dout];
            with_threads(wkr, || matmul_q8(&x, &qm, &mut ow, rows));
            assert_eq!(
                ow.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                o1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            );
        }
    }
}
