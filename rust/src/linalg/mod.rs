//! Dense linear algebra built from scratch (no BLAS/LAPACK available).
//!
//! Solvers run in f64 for numerical robustness (the paper computes
//! compensation in float32; we accumulate and solve in f64 and cast back,
//! which only tightens the closed-form identities the tests check). The
//! f32 GEMM in [`gemm`] is the calibration-statistics hot path and is the
//! Layer-3 target of the §Perf pass.

pub mod gemm;
pub mod qgemm;
pub mod chol;
pub mod eig;
pub mod svd;
pub mod ridge;
pub mod kron;

pub use chol::{cholesky_solve, Cholesky};
pub use eig::sym_eig;
pub use gemm::{matmul_f32, matmul_tn_f32, syrk_upper_f32};
pub use qgemm::{dequant, matmul_q8, matmul_q8_raw, quantize, QuantMat};
pub use svd::svd;

use std::fmt;

/// Dense row-major f64 matrix used by the solvers.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub r: usize,
    pub c: usize,
    pub a: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat[{}x{}]", self.r, self.c)
    }
}

impl Mat {
    pub fn zeros(r: usize, c: usize) -> Self {
        Self { r, c, a: vec![0.0; r * c] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(r: usize, c: usize, a: Vec<f64>) -> Self {
        assert_eq!(a.len(), r * c);
        Self { r, c, a }
    }

    pub fn from_f32(r: usize, c: usize, a: &[f32]) -> Self {
        assert_eq!(a.len(), r * c);
        Self { r, c, a: a.iter().map(|&v| v as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.a.iter().map(|&v| v as f32).collect()
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.c + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.c + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.a[i * self.c..(i + 1) * self.c]
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.c, self.r);
        for i in 0..self.r {
            for j in 0..self.c {
                out.a[j * self.r + i] = self.a[i * self.c + j];
            }
        }
        out
    }

    /// self * other. Branch-free ikj panels distributed over the worker
    /// pool; each output row is produced by exactly one worker in a fixed k
    /// order, so results are independent of the worker count.
    pub fn mul(&self, other: &Mat) -> Mat {
        assert_eq!(self.c, other.r, "mul dims {}x{} * {}x{}", self.r, self.c, other.r, other.c);
        let mut out = Mat::zeros(self.r, other.c);
        let (k, n) = (self.c, other.c);
        if out.a.is_empty() || k == 0 {
            return out;
        }
        let a = &self.a;
        let b = &other.a;
        const RB: usize = 16; // rows per parallel work unit
        crate::util::threads::parallel_chunks_mut(&mut out.a, RB * n, |panel, cpan| {
            let i0 = panel * RB;
            let rows = cpan.len() / n;
            for i in 0..rows {
                let arow = &a[(i0 + i) * k..(i0 + i + 1) * k];
                let dst = &mut cpan[i * n..(i + 1) * n];
                for (kk, &aik) in arow.iter().enumerate() {
                    let orow = &b[kk * n..(kk + 1) * n];
                    for (d, &ov) in dst.iter_mut().zip(orow) {
                        *d += aik * ov;
                    }
                }
            }
        });
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.r, self.c), (other.r, other.c));
        let a = self.a.iter().zip(&other.a).map(|(x, y)| x + y).collect();
        Mat { r: self.r, c: self.c, a }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.r, self.c), (other.r, other.c));
        let a = self.a.iter().zip(&other.a).map(|(x, y)| x - y).collect();
        Mat { r: self.r, c: self.c, a }
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat { r: self.r, c: self.c, a: self.a.iter().map(|x| x * s).collect() }
    }

    /// Add s to the diagonal (ridge).
    pub fn add_diag(&self, s: f64) -> Mat {
        assert_eq!(self.r, self.c);
        let mut out = self.clone();
        for i in 0..self.r {
            out.a[i * self.c + i] += s;
        }
        out
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.r, self.c);
        (0..self.r).map(|i| self.a[i * self.c + i]).sum()
    }

    pub fn frob(&self) -> f64 {
        self.a.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.a.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// Max |self - other|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.r, self.c), (other.r, other.c));
        self.a.iter().zip(&other.a).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    /// Symmetrize in place: (A + Aᵀ)/2 — drifts from accumulation order are
    /// removed before Cholesky/eigen decompositions.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.r, self.c);
        for i in 0..self.r {
            for j in (i + 1)..self.c {
                let m = 0.5 * (self.a[i * self.c + j] + self.a[j * self.c + i]);
                self.a[i * self.c + j] = m;
                self.a[j * self.c + i] = m;
            }
        }
    }

    /// Extract submatrix rows×cols by index lists.
    pub fn submatrix(&self, rows: &[usize], cols: &[usize]) -> Mat {
        let mut out = Mat::zeros(rows.len(), cols.len());
        for (oi, &i) in rows.iter().enumerate() {
            for (oj, &j) in cols.iter().enumerate() {
                out.a[oi * cols.len() + oj] = self.at(i, j);
            }
        }
        out
    }
}

/// Moore–Penrose pseudo-inverse of a symmetric PSD matrix via eigen
/// decomposition, used by the distortion diagnostics (Σ_SS† in Prop. C.1.1).
pub fn sym_pinv(a: &Mat, rcond: f64) -> Mat {
    let (vals, vecs) = sym_eig(a);
    let vmax = vals.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let tol = vmax * rcond;
    let n = a.r;
    let mut out = Mat::zeros(n, n);
    for k in 0..n {
        if vals[k].abs() <= tol {
            continue;
        }
        let inv = 1.0 / vals[k];
        for i in 0..n {
            let vik = vecs.at(i, k);
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out.a[i * n + j] += inv * vik * vecs.at(j, k);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};

    #[test]
    fn mul_identity() {
        let a = Mat::from_rows(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let i3 = Mat::eye(3);
        assert!(a.mul(&i3).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn mul_known() {
        let a = Mat::from_rows(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_rows(2, 2, vec![5., 6., 7., 8.]);
        let c = a.mul(&b);
        assert_eq!(c.a, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_mul_assoc_prop() {
        run_prop("linalg.(AB)^T=B^T A^T", 20, |rng| {
            let (m, k, n) = (gen::dim(rng, 1, 8), gen::dim(rng, 1, 8), gen::dim(rng, 1, 8));
            let a = Mat::from_f32(m, k, &gen::matrix(rng, m, k, 1.0));
            let b = Mat::from_f32(k, n, &gen::matrix(rng, k, n, 1.0));
            let lhs = a.mul(&b).t();
            let rhs = b.t().mul(&a.t());
            assert!(lhs.max_abs_diff(&rhs) < 1e-10);
        });
    }

    #[test]
    fn sym_pinv_recovers_inverse_on_spd() {
        run_prop("linalg.pinv=inv on SPD", 10, |rng| {
            let n = gen::dim(rng, 2, 10);
            let a = Mat::from_f32(n, n, &gen::spd(rng, n, 0.5));
            let p = sym_pinv(&a, 1e-12);
            let should_be_eye = a.mul(&p);
            assert!(should_be_eye.max_abs_diff(&Mat::eye(n)) < 1e-6, "n={n}");
        });
    }

    #[test]
    fn sym_pinv_projects_on_singular() {
        // A = diag(2, 0): pinv = diag(0.5, 0); A·A⁺·A = A.
        let a = Mat::from_rows(2, 2, vec![2., 0., 0., 0.]);
        let p = sym_pinv(&a, 1e-12);
        let apa = a.mul(&p).mul(&a);
        assert!(apa.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn add_diag_and_trace() {
        let a = Mat::eye(3).scale(2.0).add_diag(0.5);
        assert!((a.trace() - 7.5).abs() < 1e-12);
    }

    #[test]
    fn submatrix_picks() {
        let a = Mat::from_rows(3, 3, (0..9).map(|v| v as f64).collect());
        let s = a.submatrix(&[0, 2], &[1]);
        assert_eq!(s.a, vec![1., 7.]);
    }
}
