//! Cholesky factorization and SPD solves.
//!
//! All ridge systems in CORP are symmetric positive definite once λI is
//! added, so Cholesky is the workhorse solver for both the MLP compensator
//! `B (Σ_SS + λI) = Σ_PS` and the Kronecker system `(G + λI) vec(M) = h`.

use super::Mat;

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
pub struct Cholesky {
    n: usize,
    l: Vec<f64>,
}

#[derive(Debug)]
pub struct NotSpd {
    pub index: usize,
    pub pivot: f64,
}

impl std::fmt::Display for NotSpd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix is not positive definite (pivot {} at index {})", self.pivot, self.index)
    }
}

impl std::error::Error for NotSpd {}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix.
    pub fn new(a: &Mat) -> Result<Self, NotSpd> {
        assert_eq!(a.r, a.c);
        let n = a.r;
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                // s = a[i,j] − Σ_k<j l[i,k]·l[j,k]; slice dot keeps the inner
                // loop branch- and bounds-check-free so it vectorizes.
                let dot: f64 = l[i * n..i * n + j]
                    .iter()
                    .zip(&l[j * n..j * n + j])
                    .map(|(x, y)| x * y)
                    .sum();
                let s = a.at(i, j) - dot;
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(NotSpd { index: i, pivot: s });
                    }
                    l[i * n + i] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        Ok(Self { n, l })
    }

    /// Factor with escalating diagonal jitter if the matrix is numerically
    /// semi-definite (rank-deficient calibration covariances at high keep
    /// ratios). Returns the factor and the jitter that was applied.
    pub fn new_with_jitter(a: &Mat) -> (Self, f64) {
        let scale = a.trace().abs().max(1e-30) / a.r as f64;
        let mut jitter = 0.0f64;
        loop {
            let candidate = if jitter == 0.0 { a.clone() } else { a.add_diag(jitter * scale) };
            match Self::new(&candidate) {
                Ok(f) => return (f, jitter * scale),
                Err(_) => {
                    jitter = if jitter == 0.0 { 1e-10 } else { jitter * 10.0 };
                    assert!(jitter < 1.0, "cholesky jitter escalation failed");
                }
            }
        }
    }

    /// Solve A x = b for a single right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        let l = &self.l;
        // forward: L y = b (row dot over the already-solved prefix)
        let mut y = b.to_vec();
        for i in 0..n {
            let dot: f64 =
                l[i * n..i * n + i].iter().zip(&y[..i]).map(|(a, v)| a * v).sum();
            y[i] = (y[i] - dot) / l[i * n + i];
        }
        // backward: Lᵀ x = y (column access; strided by construction)
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= l[k * n + i] * y[k];
            }
            y[i] = s / l[i * n + i];
        }
        y
    }

    /// Solve A X = B — the multi-RHS path of the ridge solvers. Right-hand
    /// sides are independent, so the back-substitutions run as one parallel
    /// region over columns (B is transposed once so each worker streams a
    /// contiguous RHS). Per-column arithmetic is identical to `solve_vec`,
    /// so results do not depend on the worker count.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        assert_eq!(b.r, self.n);
        let n = self.n;
        if n == 0 || b.c == 0 {
            return Mat::zeros(b.r, b.c);
        }
        let bt = b.t(); // [c, n]: row j = RHS j
        let mut xt = Mat::zeros(b.c, n);
        crate::util::threads::parallel_chunks_mut(&mut xt.a, n, |col, row| {
            let x = self.solve_vec(bt.row(col));
            row.copy_from_slice(&x);
        });
        xt.t()
    }

    /// Solve X A = B, i.e. X = B A⁻¹ (the orientation of the MLP ridge
    /// normal equations, Eq. (24): B (Σ_SS + λI) = Σ_PS).
    ///
    /// Row-wise: x_i A = b_i ⇔ A x_iᵀ = b_iᵀ (A symmetric), and the rows of
    /// B are already contiguous right-hand sides — so this solves each
    /// output row directly on the worker pool with no transposes at all
    /// (this sits on the per-layer MLP-compensation hot path).
    pub fn solve_right(&self, b: &Mat) -> Mat {
        assert_eq!(b.c, self.n);
        let n = self.n;
        if n == 0 || b.r == 0 {
            return Mat::zeros(b.r, b.c);
        }
        let mut out = Mat::zeros(b.r, n);
        crate::util::threads::parallel_chunks_mut(&mut out.a, n, |row_i, row| {
            let x = self.solve_vec(b.row(row_i));
            row.copy_from_slice(&x);
        });
        out
    }

    pub fn log_det(&self) -> f64 {
        (0..self.n).map(|i| self.l[i * self.n + i].ln()).sum::<f64>() * 2.0
    }
}

/// Convenience: solve (A) x = b for SPD A.
pub fn cholesky_solve(a: &Mat, b: &Mat) -> Mat {
    let (f, _) = Cholesky::new_with_jitter(a);
    f.solve_mat(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};

    #[test]
    fn solve_recovers_known_solution() {
        run_prop("chol.solve recovers x", 20, |rng| {
            let n = gen::dim(rng, 1, 12);
            let a = Mat::from_f32(n, n, &gen::spd(rng, n, 0.5));
            let x_true = Mat::from_f32(n, 3, &gen::matrix(rng, n, 3, 1.0));
            let b = a.mul(&x_true);
            let f = Cholesky::new(&a).unwrap();
            let x = f.solve_mat(&b);
            assert!(x.max_abs_diff(&x_true) < 1e-5, "n={n}");
        });
    }

    #[test]
    fn solve_right_orientation() {
        run_prop("chol.solve_right = B A^-1", 15, |rng| {
            let n = gen::dim(rng, 1, 10);
            let a = Mat::from_f32(n, n, &gen::spd(rng, n, 0.5));
            let x_true = Mat::from_f32(4, n, &gen::matrix(rng, 4, n, 1.0));
            let b = x_true.mul(&a);
            let f = Cholesky::new(&a).unwrap();
            let x = f.solve_right(&b);
            assert!(x.max_abs_diff(&x_true) < 1e-5);
        });
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(2, 2, vec![1., 2., 2., 1.]); // eigenvalues 3, -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn jitter_handles_semidefinite() {
        // Rank-1 PSD matrix.
        let a = Mat::from_rows(2, 2, vec![1., 1., 1., 1.]);
        let (f, jitter) = Cholesky::new_with_jitter(&a);
        assert!(jitter > 0.0);
        // Solution should satisfy (A + jI) x = b approximately.
        let b = vec![2.0, 2.0];
        let x = f.solve_vec(&b);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn log_det_of_diagonal() {
        let a = Mat::from_rows(2, 2, vec![4., 0., 0., 9.]);
        let f = Cholesky::new(&a).unwrap();
        assert!((f.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }
}
