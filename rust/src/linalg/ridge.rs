//! Ridge regression in the exact form CORP uses.
//!
//! MLP compensation (App. B.1):  min_B ‖X̄_P − B X̄_S‖²_F + λ‖B‖²_F with the
//! closed form B = Σ_PS (Σ_SS + λI)⁻¹, solved here from the (already
//! accumulated) covariance blocks via Cholesky.
//!
//! Multi-RHS solves run the per-column back-substitutions in parallel on the
//! worker pool (see `Cholesky::solve_mat`); per-column arithmetic is
//! unchanged, so solutions are independent of the worker count.

use super::chol::Cholesky;
use super::Mat;

/// Solve B = C_ps (C_ss + λ·scale·I)⁻¹ where `scale` normalizes λ by the mean
/// diagonal of C_ss so a single λ works across layers of different magnitude
/// (the practical convention; λ is still reported in absolute terms in
/// diagnostics).
pub fn ridge_right(c_ps: &Mat, c_ss: &Mat, lambda: f64) -> Mat {
    assert_eq!(c_ss.r, c_ss.c);
    assert_eq!(c_ps.c, c_ss.r);
    let scale = (c_ss.trace() / c_ss.r.max(1) as f64).max(1e-12);
    let reg = c_ss.add_diag(lambda * scale);
    let (f, _jitter) = Cholesky::new_with_jitter(&reg);
    f.solve_right(c_ps)
}

/// Standard ridge for design-matrix inputs: min_w ‖y − Xw‖² + λ‖w‖², used by
/// baselines (GRAIL-like output reconstruction, SNOWS-like row recovery) and
/// by the dense-task heads. X is [n, d], Y is [n, k]; returns W [d, k].
pub fn ridge_fit(x: &Mat, y: &Mat, lambda: f64) -> Mat {
    assert_eq!(x.r, y.r);
    let xtx = x.t().mul(x);
    let xty = x.t().mul(y);
    let scale = (xtx.trace() / xtx.r.max(1) as f64).max(1e-12);
    let reg = xtx.add_diag(lambda * scale);
    let (f, _) = Cholesky::new_with_jitter(&reg);
    f.solve_mat(&xty)
}

/// Affine ridge fit with intercept: returns (W, b) minimizing
/// ‖Y − XW − 1bᵀ‖² + λ‖W‖², via centering (App. B.1 Eq. 22).
pub fn ridge_fit_affine(x: &Mat, y: &Mat, lambda: f64) -> (Mat, Vec<f64>) {
    let n = x.r as f64;
    let mu_x: Vec<f64> = (0..x.c).map(|j| (0..x.r).map(|i| x.at(i, j)).sum::<f64>() / n).collect();
    let mu_y: Vec<f64> = (0..y.c).map(|j| (0..y.r).map(|i| y.at(i, j)).sum::<f64>() / n).collect();
    let mut xc = x.clone();
    for i in 0..x.r {
        for j in 0..x.c {
            xc.a[i * x.c + j] -= mu_x[j];
        }
    }
    let mut yc = y.clone();
    for i in 0..y.r {
        for j in 0..y.c {
            yc.a[i * y.c + j] -= mu_y[j];
        }
    }
    let w = ridge_fit(&xc, &yc, lambda);
    // b = mu_y - Wᵀ mu_x
    let b: Vec<f64> = (0..y.c)
        .map(|j| mu_y[j] - (0..x.c).map(|i| w.at(i, j) * mu_x[i]).sum::<f64>())
        .collect();
    (w, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};

    #[test]
    fn ridge_right_matches_normal_equations() {
        run_prop("ridge.right normal eq", 15, |rng| {
            let (p, s) = (gen::dim(rng, 1, 6), gen::dim(rng, 1, 8));
            let c_ss = Mat::from_f32(s, s, &gen::spd(rng, s, 0.3));
            let c_ps = Mat::from_f32(p, s, &gen::matrix(rng, p, s, 1.0));
            let lambda = 0.01;
            let b = ridge_right(&c_ps, &c_ss, lambda);
            // Check B (C_ss + λ scale I) = C_ps.
            let scale = c_ss.trace() / s as f64;
            let lhs = b.mul(&c_ss.add_diag(lambda * scale));
            assert!(lhs.max_abs_diff(&c_ps) < 1e-7);
        });
    }

    #[test]
    fn ridge_fit_zero_lambda_interpolates() {
        run_prop("ridge.fit recovers W on exact data", 10, |rng| {
            let (n, d, k) = (30, gen::dim(rng, 1, 5), gen::dim(rng, 1, 3));
            let x = Mat::from_f32(n, d, &gen::matrix(rng, n, d, 1.0));
            let w_true = Mat::from_f32(d, k, &gen::matrix(rng, d, k, 1.0));
            let y = x.mul(&w_true);
            let w = ridge_fit(&x, &y, 1e-10);
            assert!(w.max_abs_diff(&w_true) < 1e-4);
        });
    }

    #[test]
    fn ridge_shrinks_with_lambda() {
        let mut rng = crate::util::Pcg64::new(5);
        let x = Mat::from_f32(50, 4, &gen::matrix(&mut rng, 50, 4, 1.0));
        let w_true = Mat::from_f32(4, 1, &gen::matrix(&mut rng, 4, 1, 1.0));
        let y = x.mul(&w_true);
        let w_small = ridge_fit(&x, &y, 1e-6);
        let w_big = ridge_fit(&x, &y, 100.0);
        assert!(w_big.frob() < w_small.frob());
    }

    #[test]
    fn ridge_thread_count_invariant() {
        use crate::util::threads::with_threads;
        let mut rng = crate::util::Pcg64::new(31);
        let (p, s) = (24, 48);
        let c_ss = Mat::from_f32(s, s, &gen::spd(&mut rng, s, 0.3));
        let c_ps = Mat::from_f32(p, s, &gen::matrix(&mut rng, p, s, 1.0));
        let b1 = with_threads(1, || ridge_right(&c_ps, &c_ss, 1e-2));
        for w in [2usize, 4] {
            let bw = with_threads(w, || ridge_right(&c_ps, &c_ss, 1e-2));
            assert!(bw.max_abs_diff(&b1) < 1e-10, "w={w}");
        }
    }

    #[test]
    fn affine_fit_recovers_intercept() {
        run_prop("ridge.affine recovers (W, b)", 10, |rng| {
            let (n, d, k) = (40, gen::dim(rng, 1, 4), gen::dim(rng, 1, 3));
            let x = Mat::from_f32(n, d, &gen::matrix(rng, n, d, 1.0));
            let w_true = Mat::from_f32(d, k, &gen::matrix(rng, d, k, 1.0));
            let b_true: Vec<f64> = (0..k).map(|i| (i as f64 + 1.0) * 0.7).collect();
            let mut y = x.mul(&w_true);
            for i in 0..n {
                for j in 0..k {
                    y.a[i * k + j] += b_true[j];
                }
            }
            let (w, b) = ridge_fit_affine(&x, &y, 1e-10);
            assert!(w.max_abs_diff(&w_true) < 1e-4);
            for j in 0..k {
                assert!((b[j] - b_true[j]).abs() < 1e-4);
            }
        });
    }
}
