//! Singular value decomposition.
//!
//! CORP needs the SVD of `I + M` (a small d'_h × d'_h matrix, Alg. 5) to
//! split the logit compensator symmetrically into the query and key
//! projections. We compute it from the symmetric eigendecompositions of
//! AᵀA (right vectors) with left vectors recovered as U = A V Σ⁻¹, plus a
//! null-space completion for rank-deficient inputs.

use super::eig::sym_eig;
use super::Mat;

/// Full SVD of a square matrix A = U Σ Vᵀ. Returns (U, σ, V) with σ sorted
/// descending and U, V orthogonal.
pub fn svd(a: &Mat) -> (Mat, Vec<f64>, Mat) {
    assert_eq!(a.r, a.c, "svd: only square inputs needed by CORP");
    let n = a.r;
    // Right singular vectors from AᵀA.
    let ata = a.t().mul(a);
    let (vals, v) = sym_eig(&ata);
    let sigma: Vec<f64> = vals.iter().map(|&l| l.max(0.0).sqrt()).collect();
    // U columns: A v_i / σ_i for non-trivial σ; complete the rest to an
    // orthonormal basis with modified Gram–Schmidt against existing columns.
    let tol = sigma.first().copied().unwrap_or(0.0) * 1e-12;
    let av = a.mul(&v);
    let mut u = Mat::zeros(n, n);
    let mut fixed: Vec<usize> = Vec::new();
    for i in 0..n {
        if sigma[i] > tol && sigma[i] > 0.0 {
            for r in 0..n {
                u.set(r, i, av.at(r, i) / sigma[i]);
            }
            fixed.push(i);
        }
    }
    // Null-space completion.
    for i in 0..n {
        if fixed.contains(&i) {
            continue;
        }
        // start from a unit vector not in span(existing)
        let mut best_col = vec![0.0f64; n];
        let mut best_norm = -1.0f64;
        for seed in 0..n {
            let mut cand = vec![0.0f64; n];
            cand[seed] = 1.0;
            ortho_against(&mut cand, &u, &fixed);
            let norm = cand.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > best_norm {
                best_norm = norm;
                best_col = cand;
            }
        }
        assert!(best_norm > 1e-8, "svd: failed to complete orthonormal basis");
        for r in 0..n {
            u.set(r, i, best_col[r] / best_norm);
        }
        fixed.push(i);
    }
    (u, sigma, v)
}

fn ortho_against(x: &mut [f64], u: &Mat, cols: &[usize]) {
    for &c in cols {
        let mut dot = 0.0;
        for r in 0..u.r {
            dot += x[r] * u.at(r, c);
        }
        for r in 0..u.r {
            x[r] -= dot * u.at(r, c);
        }
    }
}

/// Symmetric square-root split used by Alg. 5: given square A (here I + M),
/// return (P, Q) with P Qᵀ = A, P = U Σ^{1/2}, Q = V Σ^{1/2}.
pub fn sqrt_split(a: &Mat) -> (Mat, Mat) {
    let (u, sigma, v) = svd(a);
    let n = a.r;
    let mut p = Mat::zeros(n, n);
    let mut q = Mat::zeros(n, n);
    for j in 0..n {
        let s = sigma[j].max(0.0).sqrt();
        for i in 0..n {
            p.set(i, j, u.at(i, j) * s);
            q.set(i, j, v.at(i, j) * s);
        }
    }
    (p, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};

    #[test]
    fn reconstruction_prop() {
        run_prop("svd.A = U S V^T", 20, |rng| {
            let n = gen::dim(rng, 1, 10);
            let a = Mat::from_f32(n, n, &gen::matrix(rng, n, n, 1.0));
            let (u, s, v) = svd(&a);
            let mut d = Mat::zeros(n, n);
            for i in 0..n {
                d.set(i, i, s[i]);
            }
            let rebuilt = u.mul(&d).mul(&v.t());
            assert!(rebuilt.max_abs_diff(&a) < 1e-7 * (1.0 + a.max_abs()), "n={n}");
        });
    }

    #[test]
    fn orthogonality_prop() {
        run_prop("svd.U,V orthogonal", 15, |rng| {
            let n = gen::dim(rng, 1, 10);
            let a = Mat::from_f32(n, n, &gen::matrix(rng, n, n, 1.0));
            let (u, _, v) = svd(&a);
            assert!(u.t().mul(&u).max_abs_diff(&Mat::eye(n)) < 1e-8);
            assert!(v.t().mul(&v).max_abs_diff(&Mat::eye(n)) < 1e-8);
        });
    }

    #[test]
    fn singular_values_descending_nonneg() {
        run_prop("svd.sigma sorted", 10, |rng| {
            let n = gen::dim(rng, 2, 10);
            let a = Mat::from_f32(n, n, &gen::matrix(rng, n, n, 1.0));
            let (_, s, _) = svd(&a);
            for w in s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            assert!(s.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn rank_deficient() {
        // Rank-1 matrix: outer([1,2],[3,4]).
        let a = Mat::from_rows(2, 2, vec![3., 4., 6., 8.]);
        let (u, s, v) = svd(&a);
        assert!(s[1].abs() < 1e-10);
        let mut d = Mat::zeros(2, 2);
        d.set(0, 0, s[0]);
        assert!(u.mul(&d).mul(&v.t()).max_abs_diff(&a) < 1e-9);
        // U still orthogonal despite null-space completion.
        assert!(u.t().mul(&u).max_abs_diff(&Mat::eye(2)) < 1e-9);
    }

    #[test]
    fn sqrt_split_reconstructs_prop() {
        run_prop("svd.sqrt_split P Q^T = A", 15, |rng| {
            let n = gen::dim(rng, 1, 8);
            // I + M shape: identity plus a modest perturbation.
            let m = gen::matrix(rng, n, n, 0.3);
            let a = Mat::eye(n).add(&Mat::from_f32(n, n, &m));
            let (p, q) = sqrt_split(&a);
            assert!(p.mul(&q.t()).max_abs_diff(&a) < 1e-7);
        });
    }
}
