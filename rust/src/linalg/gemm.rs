//! Packed-panel f32 GEMM kernels for the calibration-statistics hot path.
//!
//! Calibration accumulates Gram/covariance blocks XᵀX over activation
//! matrices with thousands of rows — this is where Layer 3 spends its time
//! (Table 6: "calibration dominates"). The kernels here are the §Perf
//! rebuild of the seed's scalar loops:
//!
//! * **Packing** — each MC-row panel of A is repacked per KC-depth block
//!   into MR-interleaved micro-tiles (`pack[kk*MR + r] = A[i0+r, k0+kk]`),
//!   so the micro-kernel reads A contiguously and keeps the panel in
//!   L1/L2 across the j sweep.
//! * **Register micro-kernel** — an MR×NR (4×8) accumulator tile updated
//!   with one A broadcast and one 8-wide B row load per step; the NR-exact
//!   fast path is written as explicit `std::arch` AVX2 (one `__m256`
//!   accumulator per tile row), with the portable fixed-size-array tile
//!   kept as the always-available fallback and as the remainder path.
//! * **Runtime dispatch** — `is_x86_feature_detected!("avx2")` is probed
//!   once (cached); the `CORP_SIMD=off` env override forces the portable
//!   tile and is re-read on every top-level kernel call so tests can flip
//!   it at runtime. The AVX2 tile deliberately uses `add(mul(..))` rather
//!   than FMA: it is **bitwise identical** to the portable tile (same
//!   per-lane multiply-round-add-round sequence, same accumulation order),
//!   so dispatch never changes results — calibration Grams, compensation
//!   solves, and served predictions are invariant to the CPU the run lands
//!   on.
//! * **No zero-skip branches** — the seed kernels tested `a_ik == 0.0`
//!   inside the innermost loop, which blocked vectorization entirely;
//!   dense panels are always cheaper than a data-dependent branch.
//! * **Row-panel parallelism** — panels of C are distributed over the
//!   scoped worker pool (`util::threads`); each C row is produced by
//!   exactly one worker in a fixed k-block order, so results are bitwise
//!   identical for any worker count.
//!
//! `matmul_tn_f32` (the Gram shape C += AᵀB with A stored [k, m]) first
//! transposes A into row-major once — O(k·m) against the O(k·m·n) multiply —
//! then runs the same packed kernel. `syrk_upper_f32` packs Xᵀ and computes
//! only the block-upper triangle before mirroring. Both therefore inherit
//! the SIMD micro-kernel, as do `dot_f32` / `matvec_f32` (an 8-lane
//! accumulator with the same left-fold horizontal reduction as the
//! portable multi-accumulator).
//!
//! The seed's scalar kernels are preserved in [`reference`] as the
//! before/after baseline for `corp bench linalg` / `BENCH_linalg.json`;
//! the int8 weight-quantized sibling lives in [`super::qgemm`].

use crate::util::threads;

/// Micro-kernel rows (A values broadcast per step).
const MR: usize = 4;
/// Micro-kernel columns (B lanes per step; one AVX2 f32 vector).
const NR: usize = 8;
/// Depth block: one packed panel of A spans KC levels.
const KC: usize = 256;
/// Rows of C per parallel work unit.
const MC: usize = 64;

#[cfg(test)]
thread_local! {
    /// Test-only dispatch override (see [`force_simd`]). Read on the
    /// calling thread before the parallel region fans out, so it governs
    /// the whole kernel call.
    static SIMD_OVERRIDE: std::cell::Cell<Option<bool>> = const { std::cell::Cell::new(None) };
}

/// Run `f` with kernel dispatch pinned to SIMD (`Some(true)`, a no-op on
/// hosts without AVX2), the portable tile (`Some(false)`), or the normal
/// env/CPUID decision (`None`). Test-only: the equivalence tests use it to
/// compare both paths on one host.
#[cfg(test)]
pub(crate) fn force_simd<R>(on: Option<bool>, f: impl FnOnce() -> R) -> R {
    SIMD_OVERRIDE.with(|c| {
        let prev = c.replace(on);
        let out = f();
        c.set(prev);
        out
    })
}

/// Cached CPUID probe for AVX2. Always `false` off x86-64.
pub fn avx2_detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static AVX2: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Runtime kernel dispatch decision: AVX2 when the CPU supports it, unless
/// `CORP_SIMD=off` (or `0`) forces the portable tile. The env var is
/// re-read on every top-level kernel call (cheap next to any GEMM) so the
/// override can be flipped at runtime; the CPUID probe is cached.
pub fn simd_enabled() -> bool {
    #[cfg(test)]
    if let Some(forced) = SIMD_OVERRIDE.with(|c| c.get()) {
        return forced && avx2_detected();
    }
    if matches!(std::env::var("CORP_SIMD").as_deref(), Ok("off") | Ok("0")) {
        return false;
    }
    avx2_detected()
}

/// Label for the dispatch decision `simd_enabled` would make right now —
/// `"avx2"` or `"portable"` — recorded in the bench tables.
pub fn simd_label() -> &'static str {
    if simd_enabled() {
        "avx2"
    } else {
        "portable"
    }
}

/// C[m,n] += A[m,k] · B[k,n], all row-major.
pub fn matmul_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let simd = simd_enabled();
    threads::parallel_chunks_mut(c, MC * n, |panel, cpan| {
        let i0 = panel * MC;
        let rows = cpan.len() / n;
        gemm_panel(&a[i0 * k..(i0 + rows) * k], b, cpan, rows, k, n, 0, simd);
    });
}

/// C[m,n] += Aᵀ · B where A is stored [k, m] row-major (the Gram shape:
/// X stored [samples, channels], C += XᵀX uses a = b = X). Implemented as a
/// one-off O(k·m) transpose into row-major followed by the packed kernel.
pub fn matmul_tn_f32(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let simd = simd_enabled();
    let at = transpose(a, k, m); // [m, k]
    threads::parallel_chunks_mut(c, MC * n, |panel, cpan| {
        let i0 = panel * MC;
        let rows = cpan.len() / n;
        gemm_panel(&at[i0 * k..(i0 + rows) * k], b, cpan, rows, k, n, 0, simd);
    });
}

/// Symmetric rank-k update C += XᵀX computing the upper triangle (at panel
/// granularity) and mirroring it to the lower. X is [rows, n] row-major;
/// C is [n, n]. Parallel over row panels of C; each panel i0.. computes the
/// rectangle j ∈ [i0, n), so entries strictly below the diagonal inside a
/// panel accumulate scratch values — the final mirror overwrites the whole
/// lower triangle from the upper, preserving the accumulate-then-mirror
/// semantics of the seed kernel.
pub fn syrk_upper_f32(x: &[f32], c: &mut [f32], rows: usize, n: usize) {
    assert_eq!(x.len(), rows * n);
    assert_eq!(c.len(), n * n);
    if n == 0 {
        return;
    }
    if rows > 0 {
        let simd = simd_enabled();
        let xt = transpose(x, rows, n); // [n, rows]: row i = channel i over samples
        threads::parallel_chunks_mut(c, MC * n, |panel, cpan| {
            let i0 = panel * MC;
            let pr = cpan.len() / n;
            gemm_panel(&xt[i0 * rows..(i0 + pr) * rows], x, cpan, pr, rows, n, i0, simd);
        });
    }
    // Mirror upper -> lower.
    for i in 0..n {
        for j in (i + 1)..n {
            c[j * n + i] = c[i * n + j];
        }
    }
}

/// `y[m] += A[m,n] · x[n]`.
pub fn matvec_f32(a: &[f32], x: &[f32], y: &mut [f32], m: usize, n: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    if m == 0 {
        return;
    }
    let simd = simd_enabled();
    threads::parallel_chunks_mut(y, 128, |blk, ychunk| {
        let r0 = blk * 128;
        for (dy, yv) in ychunk.iter_mut().enumerate() {
            let row = &a[(r0 + dy) * n..(r0 + dy + 1) * n];
            *yv += dot_dispatch(row, x, simd);
        }
    });
}

/// Multi-accumulator dot product (one dispatch decision per call; `matvec`
/// amortizes the decision over all rows via [`dot_dispatch`]).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    dot_dispatch(a, b, simd_enabled())
}

#[inline]
fn dot_dispatch(a: &[f32], b: &[f32], simd: bool) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if simd {
        // Safety: `simd` is only true when the AVX2 probe succeeded.
        return unsafe { dot_avx2(a, b) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = simd;
    dot_portable(a, b)
}

/// Portable 8-lane multi-accumulator dot (vectorizes without a zero-skip
/// branch); the exact reference the AVX2 path reproduces bitwise.
#[inline]
fn dot_portable(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; NR];
    let chunks = a.len() / NR;
    for i in 0..chunks {
        let av = &a[i * NR..(i + 1) * NR];
        let bv = &b[i * NR..(i + 1) * NR];
        for j in 0..NR {
            acc[j] += av[j] * bv[j];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * NR..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// AVX2 dot: one 8-lane vector accumulator updated with `add(mul(..))` —
/// per lane the identical multiply/add/rounding sequence as
/// [`dot_portable`]'s `acc[j] += av[j] * bv[j]` — then the same sequential
/// left-fold over lanes 0..8 and the same scalar tail.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let chunks = a.len() / NR;
    let mut vacc = _mm256_setzero_ps();
    for i in 0..chunks {
        let av = _mm256_loadu_ps(a.as_ptr().add(i * NR));
        let bv = _mm256_loadu_ps(b.as_ptr().add(i * NR));
        // No FMA: fused multiply-add rounds once where the portable kernel
        // rounds twice, which would break bitwise dispatch invariance.
        vacc = _mm256_add_ps(vacc, _mm256_mul_ps(av, bv));
    }
    let mut lanes = [0.0f32; NR];
    _mm256_storeu_ps(lanes.as_mut_ptr(), vacc);
    let mut s = lanes.iter().sum::<f32>();
    for i in chunks * NR..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Blocked transpose: `src` [rows, cols] row-major → returned [cols, rows].
fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    const TB: usize = 32;
    let mut out = vec![0.0f32; src.len()];
    threads::parallel_chunks_mut(&mut out, TB * rows.max(1), |blk, ochunk| {
        let c0 = blk * TB;
        let bc = ochunk.len() / rows.max(1);
        for r0 in (0..rows).step_by(TB) {
            let r1 = (r0 + TB).min(rows);
            for (dc, och) in ochunk.chunks_mut(rows).enumerate().take(bc) {
                let col = c0 + dc;
                for r in r0..r1 {
                    och[r] = src[r * cols + col];
                }
            }
        }
    });
    out
}

/// One MC-row panel of C += A_panel · B, with columns restricted to
/// [jlo, n). `a` holds the panel's rows [rows, k] row-major; `cpan` is the
/// panel's slice of C (full n-column rows). `simd` is the dispatch decision
/// made once at the public entry point.
#[allow(clippy::too_many_arguments)]
fn gemm_panel(
    a: &[f32],
    b: &[f32],
    cpan: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
    jlo: usize,
    simd: bool,
) {
    let mut pack = [0.0f32; KC * MR];
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        let mut i = 0;
        while i < rows {
            let mr = MR.min(rows - i);
            // Pack rows i..i+mr over depth k0..k0+kc, MR-interleaved; unused
            // lanes are zero so the micro-kernel needs no row bound checks.
            for kk in 0..kc {
                for r in 0..MR {
                    pack[kk * MR + r] =
                        if r < mr { a[(i + r) * k + k0 + kk] } else { 0.0 };
                }
            }
            micro_kernel(&pack, kc, b, k0, n, jlo, cpan, i, mr, simd);
            i += mr;
        }
    }
}

/// MR×NR register-tile micro-kernel: for each NR-wide column strip of C,
/// accumulate over the packed depth block, then add into C. The NR-exact
/// strip dispatches to the AVX2 tile when `simd` is set; NR-remainder
/// strips always take the portable path (the AVX2 tile has no masked
/// loads, and remainders are a vanishing fraction of the work).
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel(
    pack: &[f32; KC * MR],
    kc: usize,
    b: &[f32],
    k0: usize,
    n: usize,
    jlo: usize,
    cpan: &mut [f32],
    i: usize,
    mr: usize,
    simd: bool,
) {
    #[cfg(not(target_arch = "x86_64"))]
    let _ = simd;
    let mut j0 = jlo;
    while j0 < n {
        let nr = NR.min(n - j0);
        let mut acc = [[0.0f32; NR]; MR];
        if nr == NR {
            let mut done = false;
            #[cfg(target_arch = "x86_64")]
            if simd {
                // Safety: `simd` is only true when the AVX2 probe
                // succeeded; `j0 + NR <= n` and `k0 + kc <= k` bound every
                // load.
                unsafe { tile_full_avx2(pack, kc, b, k0, n, j0, &mut acc) };
                done = true;
            }
            if !done {
                // Portable fast path: fixed-size B loads, fully unrolled.
                for kk in 0..kc {
                    let ap = &pack[kk * MR..kk * MR + MR];
                    let base = (k0 + kk) * n + j0;
                    let brow: &[f32; NR] = b[base..base + NR].try_into().unwrap();
                    for r in 0..MR {
                        let arv = ap[r];
                        for (jj, accv) in acc[r].iter_mut().enumerate() {
                            *accv += arv * brow[jj];
                        }
                    }
                }
            }
        } else {
            for kk in 0..kc {
                let ap = &pack[kk * MR..kk * MR + MR];
                let base = (k0 + kk) * n + j0;
                let brow = &b[base..base + nr];
                for r in 0..MR {
                    let arv = ap[r];
                    for (jj, &bv) in brow.iter().enumerate() {
                        acc[r][jj] += arv * bv;
                    }
                }
            }
        }
        for r in 0..mr {
            let crow = &mut cpan[(i + r) * n + j0..(i + r) * n + j0 + nr];
            for (jj, cv) in crow.iter_mut().enumerate() {
                *cv += acc[r][jj];
            }
        }
        j0 += nr;
    }
}

/// AVX2 NR-exact tile: one `__m256` accumulator per tile row, updated with
/// a broadcast A value and an unaligned 8-wide B load per depth step.
/// `add(mul(..))` keeps each lane's rounding sequence identical to the
/// portable tile; the accumulation order (kk outer, row inner, lane-wise)
/// is also identical, so the stored `acc` is bitwise what the portable
/// path produces.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile_full_avx2(
    pack: &[f32; KC * MR],
    kc: usize,
    b: &[f32],
    k0: usize,
    n: usize,
    j0: usize,
    acc: &mut [[f32; NR]; MR],
) {
    use std::arch::x86_64::*;
    debug_assert!((k0 + kc - 1) * n + j0 + NR <= b.len());
    let mut vacc = [_mm256_setzero_ps(); MR];
    for kk in 0..kc {
        let bv = _mm256_loadu_ps(b.as_ptr().add((k0 + kk) * n + j0));
        for (r, va) in vacc.iter_mut().enumerate() {
            let av = _mm256_set1_ps(pack[kk * MR + r]);
            *va = _mm256_add_ps(*va, _mm256_mul_ps(av, bv));
        }
    }
    for (r, va) in vacc.iter().enumerate() {
        _mm256_storeu_ps(acc[r].as_mut_ptr(), *va);
    }
}

/// The seed's scalar kernels (branchy ikj / rank-1 loops), kept verbatim as
/// the measured "before" baseline for the `bench linalg` harness and the
/// equivalence property tests. Not used on any hot path.
pub mod reference {
    /// Seed `matmul_f32`: blocked ikj with an `a_ik == 0` skip branch.
    pub fn matmul_f32_seed(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        const MC: usize = 64;
        const KC: usize = 256;
        for i0 in (0..m).step_by(MC) {
            let i1 = (i0 + MC).min(m);
            for k0 in (0..k).step_by(KC) {
                let k1 = (k0 + KC).min(k);
                for i in i0..i1 {
                    let arow = &a[i * k..(i + 1) * k];
                    let crow = &mut c[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * n..(kk + 1) * n];
                        for j in 0..n {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }

    /// Seed `matmul_tn_f32`: per-sample rank-1 updates with a skip branch.
    pub fn matmul_tn_f32_seed(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
        assert_eq!(a.len(), k * m);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        for kk in 0..k {
            let arow = &a[kk * m..(kk + 1) * m];
            let brow = &b[kk * n..(kk + 1) * n];
            for i in 0..m {
                let aik = arow[i];
                if aik == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    }

    /// Seed `syrk_upper_f32`: row-streamed upper-triangle rank-1 updates.
    pub fn syrk_upper_f32_seed(x: &[f32], c: &mut [f32], rows: usize, n: usize) {
        assert_eq!(x.len(), rows * n);
        assert_eq!(c.len(), n * n);
        for r in 0..rows {
            let xr = &x[r * n..(r + 1) * n];
            for i in 0..n {
                let xi = xr[i];
                if xi == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n + i..i * n + n];
                let xj = &xr[i..n];
                for (cv, &bv) in crow.iter_mut().zip(xj) {
                    *cv += xi * bv;
                }
            }
        }
        for i in 0..n {
            for j in (i + 1)..n {
                c[j * n + i] = c[i * n + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};
    use crate::util::threads::with_threads;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len());
        for (x, y) in got.iter().zip(want) {
            assert!((x - y).abs() < tol * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_prop() {
        run_prop("gemm.matmul=naive", 25, |rng| {
            let (m, k, n) = (gen::dim(rng, 1, 20), gen::dim(rng, 1, 30), gen::dim(rng, 1, 20));
            let a = gen::matrix(rng, m, k, 1.0);
            let b = gen::matrix(rng, k, n, 1.0);
            let mut c = vec![0.0; m * n];
            matmul_f32(&a, &b, &mut c, m, k, n);
            assert_close(&c, &naive(&a, &b, m, k, n), 1e-3);
        });
    }

    #[test]
    fn matmul_matches_naive_large_dims() {
        // Exercises multiple row panels, KC blocking, and NR remainders.
        run_prop("gemm.matmul=naive large", 4, |rng| {
            let (m, k, n) =
                (gen::dim(rng, 65, 150), gen::dim(rng, 200, 300), gen::dim(rng, 30, 90));
            let a = gen::matrix(rng, m, k, 1.0);
            let b = gen::matrix(rng, k, n, 1.0);
            let mut c = vec![0.0; m * n];
            matmul_f32(&a, &b, &mut c, m, k, n);
            assert_close(&c, &naive(&a, &b, m, k, n), 1e-3);
        });
    }

    #[test]
    fn matmul_tn_matches_transpose_then_mul() {
        run_prop("gemm.tn=t(a)*b", 20, |rng| {
            let (k, m, n) = (gen::dim(rng, 1, 24), gen::dim(rng, 1, 12), gen::dim(rng, 1, 12));
            let a = gen::matrix(rng, k, m, 1.0); // stored [k, m]
            let b = gen::matrix(rng, k, n, 1.0);
            let mut c = vec![0.0; m * n];
            matmul_tn_f32(&a, &b, &mut c, k, m, n);
            // reference: transpose a then multiply
            let mut at = vec![0.0; m * k];
            for i in 0..k {
                for j in 0..m {
                    at[j * k + i] = a[i * m + j];
                }
            }
            assert_close(&c, &naive(&at, &b, m, k, n), 1e-3);
        });
    }

    #[test]
    fn syrk_matches_tn_self() {
        run_prop("gemm.syrk=xtx", 20, |rng| {
            let (rows, n) = (gen::dim(rng, 1, 30), gen::dim(rng, 1, 16));
            let x = gen::matrix(rng, rows, n, 1.0);
            let mut c1 = vec![0.0; n * n];
            syrk_upper_f32(&x, &mut c1, rows, n);
            let mut c2 = vec![0.0; n * n];
            matmul_tn_f32(&x, &x, &mut c2, rows, n, n);
            for (a, b) in c1.iter().zip(&c2) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
            }
        });
    }

    #[test]
    fn syrk_matches_tn_self_large() {
        run_prop("gemm.syrk=xtx large", 3, |rng| {
            let (rows, n) = (gen::dim(rng, 150, 400), gen::dim(rng, 70, 140));
            let x = gen::matrix(rng, rows, n, 1.0);
            let mut c1 = vec![0.0; n * n];
            syrk_upper_f32(&x, &mut c1, rows, n);
            let mut c2 = vec![0.0; n * n];
            matmul_tn_f32(&x, &x, &mut c2, rows, n, n);
            for (a, b) in c1.iter().zip(&c2) {
                assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()));
            }
        });
    }

    #[test]
    fn packed_matches_seed_reference() {
        run_prop("gemm.packed=seed", 8, |rng| {
            let (m, k, n) = (gen::dim(rng, 1, 70), gen::dim(rng, 1, 90), gen::dim(rng, 1, 50));
            let a = gen::matrix(rng, m, k, 1.0);
            let b = gen::matrix(rng, k, n, 1.0);
            let mut c_new = vec![0.0; m * n];
            matmul_f32(&a, &b, &mut c_new, m, k, n);
            let mut c_seed = vec![0.0; m * n];
            reference::matmul_f32_seed(&a, &b, &mut c_seed, m, k, n);
            assert_close(&c_new, &c_seed, 1e-3);
        });
    }

    /// Tentpole acceptance: the AVX2 path is **bitwise** identical to the
    /// portable tile across shapes straddling the MR=4 / NR=8 / KC=256
    /// boundaries (row remainders, column remainders, multi-KC depth).
    /// Trivially passes on hosts without AVX2 (both runs take the portable
    /// tile).
    #[test]
    fn simd_matches_portable_bitwise() {
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 7, 7),
            (4, 8, 8),
            (5, 9, 9),
            (8, 255, 16),
            (9, 256, 17),
            (12, 257, 24),
            (13, 300, 31),
            (64, 512, 40),
            (65, 513, 41),
        ];
        let mut rng = crate::util::Pcg64::new(77);
        for &(m, k, n) in &shapes {
            let a = gen::matrix(&mut rng, m, k, 1.0);
            let b = gen::matrix(&mut rng, k, n, 1.0);
            let mut c_simd = vec![0.0f32; m * n];
            force_simd(Some(true), || matmul_f32(&a, &b, &mut c_simd, m, k, n));
            let mut c_port = vec![0.0f32; m * n];
            force_simd(Some(false), || matmul_f32(&a, &b, &mut c_port, m, k, n));
            assert_eq!(
                c_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                c_port.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "matmul simd!=portable at m={m} k={k} n={n}"
            );

            // tn / syrk / matvec funnel through the same micro-kernel and
            // dot; check them on the same straddling shapes.
            let x = gen::matrix(&mut rng, k, n, 1.0);
            let mut s_simd = vec![0.0f32; n * n];
            force_simd(Some(true), || syrk_upper_f32(&x, &mut s_simd, k, n));
            let mut s_port = vec![0.0f32; n * n];
            force_simd(Some(false), || syrk_upper_f32(&x, &mut s_port, k, n));
            assert_eq!(
                s_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                s_port.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "syrk simd!=portable at rows={k} n={n}"
            );

            let xv = gen::matrix(&mut rng, 1, k, 1.0);
            let av = gen::matrix(&mut rng, m, k, 1.0);
            let mut y_simd = vec![0.0f32; m];
            force_simd(Some(true), || matvec_f32(&av, &xv, &mut y_simd, m, k));
            let mut y_port = vec![0.0f32; m];
            force_simd(Some(false), || matvec_f32(&av, &xv, &mut y_port, m, k));
            assert_eq!(
                y_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y_port.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "matvec simd!=portable at m={m} n={k}"
            );
        }
    }

    #[test]
    fn tn_simd_matches_portable_bitwise() {
        let mut rng = crate::util::Pcg64::new(78);
        for &(k, m, n) in &[(255usize, 5usize, 9usize), (257, 12, 16), (64, 33, 40)] {
            let a = gen::matrix(&mut rng, k, m, 1.0);
            let b = gen::matrix(&mut rng, k, n, 1.0);
            let mut c_simd = vec![0.0f32; m * n];
            force_simd(Some(true), || matmul_tn_f32(&a, &b, &mut c_simd, k, m, n));
            let mut c_port = vec![0.0f32; m * n];
            force_simd(Some(false), || matmul_tn_f32(&a, &b, &mut c_port, k, m, n));
            assert_eq!(
                c_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                c_port.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "tn simd!=portable at k={k} m={m} n={n}"
            );
        }
    }

    /// `CORP_SIMD=off` forces the portable tile through the env path (as
    /// opposed to the test override). Safe under parallel tests: dispatch
    /// is bitwise result-invariant, so other tests racing this env flip
    /// cannot observe a difference.
    #[test]
    fn corp_simd_off_env_forces_fallback() {
        let mut rng = crate::util::Pcg64::new(79);
        let (m, k, n) = (9, 260, 17);
        let a = gen::matrix(&mut rng, m, k, 1.0);
        let b = gen::matrix(&mut rng, k, n, 1.0);
        std::env::set_var("CORP_SIMD", "off");
        assert!(!simd_enabled(), "CORP_SIMD=off must force the portable tile");
        assert_eq!(simd_label(), "portable");
        let mut c_off = vec![0.0f32; m * n];
        matmul_f32(&a, &b, &mut c_off, m, k, n);
        std::env::remove_var("CORP_SIMD");
        let mut c_on = vec![0.0f32; m * n];
        matmul_f32(&a, &b, &mut c_on, m, k, n);
        assert_eq!(
            c_off.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            c_on.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn dot_simd_matches_portable_bitwise() {
        let mut rng = crate::util::Pcg64::new(80);
        for len in [0usize, 1, 7, 8, 9, 16, 17, 255, 256, 257, 1000] {
            let a = gen::matrix(&mut rng, 1, len.max(1), 1.0);
            let b = gen::matrix(&mut rng, 1, len.max(1), 1.0);
            let (a, b) = (&a[..len], &b[..len]);
            let s = force_simd(Some(true), || dot_f32(a, b));
            let p = force_simd(Some(false), || dot_f32(a, b));
            assert_eq!(s.to_bits(), p.to_bits(), "dot simd!=portable at len={len}");
        }
    }

    #[test]
    fn thread_count_invariance() {
        // Acceptance: parallel kernels agree across worker counts. The row
        // ownership scheme makes GEMM/SYRK bitwise reproducible, but only
        // f32-tolerance equality is asserted.
        run_prop("gemm.thread invariance", 4, |rng| {
            let (m, k, n) =
                (gen::dim(rng, 60, 130), gen::dim(rng, 100, 280), gen::dim(rng, 40, 100));
            let a = gen::matrix(rng, m, k, 1.0);
            let b = gen::matrix(rng, k, n, 1.0);
            let mut c1 = vec![0.0; m * n];
            with_threads(1, || matmul_f32(&a, &b, &mut c1, m, k, n));
            for w in [2usize, 4, 8] {
                let mut cw = vec![0.0; m * n];
                with_threads(w, || matmul_f32(&a, &b, &mut cw, m, k, n));
                assert_close(&cw, &c1, 1e-5);
            }
            let rows = 190;
            let x = gen::matrix(rng, rows, n, 1.0);
            let mut s1 = vec![0.0; n * n];
            with_threads(1, || syrk_upper_f32(&x, &mut s1, rows, n));
            let mut s4 = vec![0.0; n * n];
            with_threads(4, || syrk_upper_f32(&x, &mut s4, rows, n));
            assert_close(&s4, &s1, 1e-5);
        });
    }

    #[test]
    fn matvec_known() {
        let a = [1., 2., 3., 4.];
        let x = [1., 1.];
        let mut y = vec![0.0; 2];
        matvec_f32(&a, &x, &mut y, 2, 2);
        assert_eq!(y, vec![3., 7.]);
    }

    #[test]
    fn matvec_matches_naive_prop() {
        run_prop("gemm.matvec=naive", 10, |rng| {
            let (m, n) = (gen::dim(rng, 1, 300), gen::dim(rng, 1, 40));
            let a = gen::matrix(rng, m, n, 1.0);
            let x = gen::matrix(rng, 1, n, 1.0);
            let mut y = vec![0.0f32; m];
            matvec_f32(&a, &x, &mut y, m, n);
            for i in 0..m {
                let want: f64 =
                    (0..n).map(|j| a[i * n + j] as f64 * x[j] as f64).sum::<f64>();
                assert!((y[i] as f64 - want).abs() < 1e-3 * (1.0 + want.abs()));
            }
        });
    }

    #[test]
    fn accumulation_semantics() {
        // C += A*B accumulates into existing C.
        let a = [1.0f32];
        let b = [2.0f32];
        let mut c = vec![10.0f32];
        matmul_f32(&a, &b, &mut c, 1, 1, 1);
        assert_eq!(c[0], 12.0);
    }

    #[test]
    fn syrk_accumulates_across_calls() {
        // Two accumulation calls equal one call on the concatenated data
        // (the MomentAccumulator streaming pattern).
        let mut rng = crate::util::Pcg64::new(42);
        let (r1, r2, n) = (37, 21, 19);
        let x1 = gen::matrix(&mut rng, r1, n, 1.0);
        let x2 = gen::matrix(&mut rng, r2, n, 1.0);
        let mut c_stream = vec![0.0; n * n];
        syrk_upper_f32(&x1, &mut c_stream, r1, n);
        syrk_upper_f32(&x2, &mut c_stream, r2, n);
        let mut xall = x1.clone();
        xall.extend_from_slice(&x2);
        let mut c_once = vec![0.0; n * n];
        syrk_upper_f32(&xall, &mut c_once, r1 + r2, n);
        for (a, b) in c_stream.iter().zip(&c_once) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = crate::util::Pcg64::new(3);
        for len in [0usize, 1, 7, 8, 9, 63, 100] {
            let a = gen::matrix(&mut rng, 1, len.max(1), 1.0);
            let b = gen::matrix(&mut rng, 1, len.max(1), 1.0);
            let (a, b) = (&a[..len], &b[..len]);
            let want: f64 = a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum();
            assert!((dot_f32(a, b) as f64 - want).abs() < 1e-4 * (1.0 + want.abs()));
        }
    }
}
