//! f32 GEMM kernels for the calibration-statistics hot path.
//!
//! Calibration accumulates Gram/covariance blocks XᵀX over activation
//! matrices with thousands of rows — this is where Layer 3 spends its time
//! (Table 6: "calibration dominates"), so these kernels are written with
//! register blocking + cache tiling and are the subject of the §Perf pass.

/// C[m,n] += A[m,k] * B[k,n], all row-major.
///
/// Blocked ikj with a 4-wide register accumulation over j; on a single core
/// this reaches a useful fraction of scalar peak and vectorizes with -O3.
pub fn matmul_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    const MC: usize = 64; // rows of A per block
    const KC: usize = 256; // depth per block
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            for i in i0..i1 {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    // Let LLVM vectorize this FMA loop.
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

/// C[m,n] += Aᵀ[m,k]·B[k,n] where A is stored [k, m] row-major
/// (i.e. C = AᵀB). This is the Gram-accumulation shape: X stored
/// [samples, channels], C += XᵀX uses a = b = X.
pub fn matmul_tn_f32(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    // Accumulate rank-1 updates row-by-row of the sample axis; for each
    // sample the update C += a_rowᵀ · b_row streams C once. Blocking over the
    // sample axis keeps b_row/a_row hot.
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = arow[i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Upper-triangular symmetric rank-k update: C += XᵀX computing only j >= i,
/// then mirrored. X is [rows, n] row-major; C is [n, n].
pub fn syrk_upper_f32(x: &[f32], c: &mut [f32], rows: usize, n: usize) {
    assert_eq!(x.len(), rows * n);
    assert_eq!(c.len(), n * n);
    for r in 0..rows {
        let xr = &x[r * n..(r + 1) * n];
        for i in 0..n {
            let xi = xr[i];
            if xi == 0.0 {
                continue;
            }
            let crow = &mut c[i * n + i..i * n + n];
            let xj = &xr[i..n];
            for (cv, &bv) in crow.iter_mut().zip(xj) {
                *cv += xi * bv;
            }
        }
    }
    // Mirror to lower triangle.
    for i in 0..n {
        for j in (i + 1)..n {
            c[j * n + i] = c[i * n + j];
        }
    }
}

/// y[m] += A[m,n] · x[n].
pub fn matvec_f32(a: &[f32], x: &[f32], y: &mut [f32], m: usize, n: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        let mut s = 0.0f32;
        for j in 0..n {
            s += row[j] * x[j];
        }
        y[i] += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_prop() {
        run_prop("gemm.matmul=naive", 25, |rng| {
            let (m, k, n) = (gen::dim(rng, 1, 20), gen::dim(rng, 1, 30), gen::dim(rng, 1, 20));
            let a = gen::matrix(rng, m, k, 1.0);
            let b = gen::matrix(rng, k, n, 1.0);
            let mut c = vec![0.0; m * n];
            matmul_f32(&a, &b, &mut c, m, k, n);
            let expect = naive(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&expect) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
            }
        });
    }

    #[test]
    fn matmul_tn_matches_transpose_then_mul() {
        run_prop("gemm.tn=t(a)*b", 20, |rng| {
            let (k, m, n) = (gen::dim(rng, 1, 24), gen::dim(rng, 1, 12), gen::dim(rng, 1, 12));
            let a = gen::matrix(rng, k, m, 1.0); // stored [k, m]
            let b = gen::matrix(rng, k, n, 1.0);
            let mut c = vec![0.0; m * n];
            matmul_tn_f32(&a, &b, &mut c, k, m, n);
            // reference: transpose a then multiply
            let mut at = vec![0.0; m * k];
            for i in 0..k {
                for j in 0..m {
                    at[j * k + i] = a[i * m + j];
                }
            }
            let expect = naive(&at, &b, m, k, n);
            for (x, y) in c.iter().zip(&expect) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()));
            }
        });
    }

    #[test]
    fn syrk_matches_tn_self() {
        run_prop("gemm.syrk=xtx", 20, |rng| {
            let (rows, n) = (gen::dim(rng, 1, 30), gen::dim(rng, 1, 16));
            let x = gen::matrix(rng, rows, n, 1.0);
            let mut c1 = vec![0.0; n * n];
            syrk_upper_f32(&x, &mut c1, rows, n);
            let mut c2 = vec![0.0; n * n];
            matmul_tn_f32(&x, &x, &mut c2, rows, n, n);
            for (a, b) in c1.iter().zip(&c2) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
            }
        });
    }

    #[test]
    fn matvec_known() {
        let a = [1., 2., 3., 4.];
        let x = [1., 1.];
        let mut y = vec![0.0; 2];
        matvec_f32(&a, &x, &mut y, 2, 2);
        assert_eq!(y, vec![3., 7.]);
    }

    #[test]
    fn accumulation_semantics() {
        // C += A*B accumulates into existing C.
        let a = [1.0f32];
        let b = [2.0f32];
        let mut c = vec![10.0f32];
        matmul_f32(&a, &b, &mut c, 1, 1, 1);
        assert_eq!(c[0], 12.0);
    }
}
