//! CORP: Closed-form One-shot Representation-Preserving Structured Pruning.
//!
//! Three-layer reproduction of the CORP paper (Zhang & Yang, 2026):
//!
//! * **Layer 1** (build time): Pallas kernels for attention / MLP / layernorm /
//!   Gram accumulation, lowered inside the Layer-2 JAX graphs.
//! * **Layer 2** (build time): JAX transformer blocks, AOT-lowered to HLO text
//!   artifacts (`make artifacts`).
//! * **Layer 3** (this crate): the Rust coordinator — it owns the weights, the
//!   calibration pipeline, ranking, the closed-form ridge compensation solvers,
//!   weight folding, the batched inference engine and the evaluation harness.
//!   Python never runs on the request path.

pub mod util;
pub mod tensor;
pub mod linalg;
pub mod stats;
pub mod model;
pub mod runtime;
pub mod exec;
pub mod data;
pub mod train;
pub mod rank;
pub mod compensate;
pub mod prune;
pub mod eval;
pub mod serve;
pub mod coordinator;
pub mod flops;
pub mod bench_tables;

pub mod cli_main;
pub use cli_main::run_cli;
