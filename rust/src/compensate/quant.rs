//! Closed-form dequant correction for the int8 `quantize` weight transform.
//!
//! Per-output-channel int8 quantization of `mlp.w2` replaces the exact
//! column w_j with a dequantized ŵ_j = s_j·q_j. On calibration activations
//! x with second moment G = E[xxᵀ] and mean μ (the *same* accumulators the
//! pruning compensator uses, `stats::MomentAccumulator`), the quantized
//! output u_j = xᵀŵ_j drifts from the exact t_j = xᵀw_j. The best affine
//! repair t_j ≈ g_j·u_j + c_j has the 1-D ridge closed form
//!
//!   g_j = Cov(u_j, t_j) / (Var(u_j) + λ·s̄),   c_j = E[t_j] − g_j·E[u_j]
//!
//! with every moment read off G and μ:  E[u t] = ŵᵀGw,  E[u²] = ŵᵀGŵ,
//! E[u] = μᵀŵ,  E[t] = μᵀw. The fit folds *into the stored artifacts* —
//! `scales[j] *= g_j` and `b2[j] += c_j` — so serving pays nothing: the
//! int8 GEMM epilogue already multiplies by `scales` and the bias add was
//! already there. A per-column no-harm guard keeps the identity (g=1, c=0)
//! whenever the fit would not reduce the calibration-set residual, so the
//! corrected store is never worse than plain quantization on the
//! calibration distribution.
//!
//! Only `mlp.w2` is corrected: it is the one quantized GEMM whose input
//! moments calibration captures exactly (the MLP hidden Gram). The other
//! five projections keep their plain per-channel scales — their inputs are
//! LayerNorm outputs with no accumulated Gram, and their quantization error
//! is already bounded by the per-channel step.
//!
//! For pruned stores the hidden Gram is subset to the kept channels
//! (`mlp_kept_indices` re-derives the kept set from the cached calibration
//! exactly as `prune` ranked it — ranking is deterministic), which is the
//! standard CORP approximation: compensators are fitted on dense
//! calibration statistics and applied to the pruned network.

use anyhow::{bail, Result};

use crate::linalg::qgemm::{dequant, QuantMat};
use crate::linalg::Mat;
use crate::model::{ModelConfig, QuantStore, WeightStore};
use crate::prune::{CalibStats, PruneOpts};
use crate::rank::{partition_k, score_mlp_zoo};
use crate::tensor::Tensor;

/// Fitted per-output-channel affine repair of one quantized `mlp.w2`.
pub struct QuantCorrection {
    /// Per-channel gain g_j, folded into the stored scales.
    pub gains: Vec<f32>,
    /// Per-channel offset c_j, folded into `mlp.b2`.
    pub offsets: Vec<f32>,
    /// Calibration-set residual Σ_j E[(t_j − u_j)²] of plain dequant.
    pub mse_identity: f64,
    /// Residual after the affine repair (never above `mse_identity`).
    pub mse_fitted: f64,
}

/// Aggregate report of a corrected quantization pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct QuantReport {
    /// Layers whose `mlp.w2` received a correction fold.
    pub layers_corrected: usize,
    pub mse_identity: f64,
    pub mse_fitted: f64,
}

/// Quantize a (dense or pruned+compensated) store with plain per-channel
/// scales — the uncorrected `quantize` transform.
pub fn quantize_weights(cfg: &ModelConfig, w: &WeightStore) -> Result<QuantStore> {
    QuantStore::from_store(cfg, w)
}

/// Fit the affine dequant repair for one quantized `w2` against the input
/// second moment `gram` = E[xxᵀ] and mean `μ` (widths must match the stored
/// `w2` rows). Pure closed form; no data pass.
pub fn fit_dequant_correction(
    w2: &Tensor,
    qm: &QuantMat,
    gram: &Mat,
    mean: &[f64],
    lambda: f64,
) -> QuantCorrection {
    let (o, d) = (w2.shape()[0], w2.shape()[1]);
    assert_eq!((qm.din, qm.dout), (o, d), "quantized shape mismatch");
    assert_eq!((gram.r, gram.c), (o, o), "gram width mismatch");
    assert_eq!(mean.len(), o, "mean width mismatch");
    let wf = Mat::from_f32(o, d, w2.data());
    let wq = Mat::from_f32(o, d, &dequant(qm));
    // One [o,o]·[o,d] product per side; every per-channel moment is then a
    // column dot, so the whole fit costs two GEMMs per layer.
    let gw = gram.mul(&wf);
    let gq = gram.mul(&wq);

    // Per-channel second moments, then a shared ridge normalizer so one λ
    // works across channels of different magnitude (the `ridge_right`
    // convention).
    let mut moms = Vec::with_capacity(d);
    let mut var_sum = 0.0f64;
    for j in 0..d {
        let (mut e_ut, mut e_uu, mut e_tt, mut e_u, mut e_t) = (0.0f64, 0.0, 0.0, 0.0, 0.0);
        for i in 0..o {
            e_ut += wq.at(i, j) * gw.at(i, j);
            e_uu += wq.at(i, j) * gq.at(i, j);
            e_tt += wf.at(i, j) * gw.at(i, j);
            e_u += mean[i] * wq.at(i, j);
            e_t += mean[i] * wf.at(i, j);
        }
        var_sum += (e_uu - e_u * e_u).max(0.0);
        moms.push((e_ut, e_uu, e_tt, e_u, e_t));
    }
    let var_scale = (var_sum / d.max(1) as f64).max(1e-12);

    let mut gains = Vec::with_capacity(d);
    let mut offsets = Vec::with_capacity(d);
    let (mut mse_identity, mut mse_fitted) = (0.0f64, 0.0f64);
    // Residual of t ≈ g·u + c given the raw moments.
    let mse_of = |g: f64, c: f64, m: &(f64, f64, f64, f64, f64)| -> f64 {
        let (e_ut, e_uu, e_tt, e_u, e_t) = *m;
        e_tt - 2.0 * g * e_ut - 2.0 * c * e_t + g * g * e_uu + 2.0 * g * c * e_u + c * c
    };
    for m in &moms {
        let (e_ut, e_uu, _e_tt, e_u, e_t) = *m;
        let var_u = (e_uu - e_u * e_u).max(0.0);
        let cov = e_ut - e_u * e_t;
        let (mut g, mut c) = if var_u > 1e-12 * var_scale {
            let g = (cov / (var_u + lambda * var_scale)).clamp(0.25, 4.0);
            (g, e_t - g * e_u)
        } else {
            // Degenerate channel (zero weight column or constant input):
            // the offset alone absorbs any constant drift.
            (1.0, e_t - e_u)
        };
        let id = mse_of(1.0, 0.0, m).max(0.0);
        let fit = mse_of(g, c, m).max(0.0);
        // No-harm guard: keep plain dequant when the ridge-shrunk fit would
        // not reduce the calibration residual.
        if fit > id {
            g = 1.0;
            c = 0.0;
        }
        mse_identity += id;
        mse_fitted += fit.min(id);
        gains.push(g as f32);
        offsets.push(c as f32);
    }
    QuantCorrection { gains, offsets, mse_identity, mse_fitted }
}

/// The kept MLP hidden channels per layer for a store pruned at
/// `opts.sparsity` — re-derived from the cached calibration with the same
/// deterministic ranking `prune` used, so the indices match the stored `w2`
/// rows exactly. Identity when the MLP scope is unpruned.
pub fn mlp_kept_indices(
    cfg: &ModelConfig,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
) -> Result<Vec<Vec<usize>>> {
    if stats.layers.len() != cfg.layers {
        bail!("mlp_kept_indices: {} layer stats for {} layers", stats.layers.len(), cfg.layers);
    }
    let mut out = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        let keep = opts.mlp_keep(cfg, l);
        if keep >= cfg.mlp {
            out.push((0..cfg.mlp).collect());
            continue;
        }
        let ls = &stats.layers[l];
        let w2 = dense.expect(&format!("blocks.{l}.mlp.w2"))?;
        let scores =
            score_mlp_zoo(opts.criterion, &ls.hidden, &ls.active.active_prob(), w2, opts.lambda);
        let (kept, _pruned) = partition_k(&scores, keep);
        out.push(kept);
    }
    Ok(out)
}

/// Quantize `w` and fold the closed-form dequant correction into every
/// layer's `mlp.w2` scales and `mlp.b2`. `kept[l]` maps the stored layer-l
/// `w2` rows to dense hidden channel indices (identity for unpruned
/// stores; [`mlp_kept_indices`] for pruned ones) — the calibration Gram is
/// subset accordingly.
pub fn quantize_weights_corrected(
    cfg: &ModelConfig,
    w: &WeightStore,
    stats: &CalibStats,
    kept: &[Vec<usize>],
    lambda: f64,
) -> Result<(QuantStore, QuantReport)> {
    if stats.layers.len() != cfg.layers || kept.len() != cfg.layers {
        bail!(
            "dequant correction: {} layer stats / {} kept sets for {} layers",
            stats.layers.len(),
            kept.len(),
            cfg.layers
        );
    }
    let mut qs = QuantStore::from_store(cfg, w)?;
    let mut report = QuantReport::default();
    for l in 0..cfg.layers {
        let name = format!("blocks.{l}.mlp.w2");
        let w2 = w.expect(&name)?;
        let o = w2.shape()[0];
        let idx = &kept[l];
        if idx.len() != o {
            bail!("dequant correction: layer {l} kept {} channels, stored w2 has {o} rows", idx.len());
        }
        let hidden = &stats.layers[l].hidden;
        if idx.iter().any(|&i| i >= hidden.d) {
            bail!("dequant correction: layer {l} kept index out of range (gram width {})", hidden.d);
        }
        let full_gram = hidden.second_moment();
        let full_mean = hidden.mean();
        let identity = o == hidden.d && idx.iter().enumerate().all(|(i, &v)| i == v);
        let (gram, mean) = if identity {
            (full_gram, full_mean)
        } else {
            (full_gram.submatrix(idx, idx), idx.iter().map(|&i| full_mean[i]).collect())
        };
        let corr = fit_dequant_correction(w2, qs.expect_q(&name)?, &gram, &mean, lambda);
        {
            let qm = qs.get_q_mut(&name).expect("quantized w2 present");
            for (s, &g) in qm.scales.iter_mut().zip(&corr.gains) {
                *s *= g;
            }
        }
        let b2_name = format!("blocks.{l}.mlp.b2");
        let mut b2 = qs.base().expect(&b2_name)?.data().to_vec();
        for (b, &c) in b2.iter_mut().zip(&corr.offsets) {
            *b += c;
        }
        let len = b2.len();
        qs.base_mut().insert(b2_name, Tensor::from_vec(&[len], b2));
        report.layers_corrected += 1;
        report.mse_identity += corr.mse_identity;
        report.mse_fitted += corr.mse_fitted;
    }
    Ok((qs, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qgemm::quantize;
    use crate::stats::MomentAccumulator;
    use crate::util::prop::{gen, run_prop};
    use crate::util::Pcg64;

    fn moments(x: &[f32], rows: usize, o: usize) -> (Mat, Vec<f64>) {
        let mut acc = MomentAccumulator::new(o);
        acc.add_batch(x, rows);
        (acc.second_moment(), acc.mean())
    }

    /// The fitted residual never exceeds plain dequant's on the calibration
    /// moments themselves — the no-harm guard, as a property.
    #[test]
    fn fit_never_worse_than_identity() {
        run_prop("quant.fit no-harm", 8, |rng| {
            let o = 8 + rng.below(24);
            let d = 2 + rng.below(6);
            let rows = 200;
            let x = gen::matrix(rng, rows, o, 1.0);
            let (gram, mean) = moments(&x, rows, o);
            let w2 = Tensor::from_vec(&[o, d], gen::matrix(rng, o, d, 1.0));
            let qm = quantize(w2.data(), o, d);
            let corr = fit_dequant_correction(&w2, &qm, &gram, &mean, 1e-2);
            assert!(
                corr.mse_fitted <= corr.mse_identity * (1.0 + 1e-3) + 1e-9,
                "fitted {} identity {}",
                corr.mse_fitted,
                corr.mse_identity
            );
            // Quantization is a near-identity perturbation: gains hug 1.
            for &g in &corr.gains {
                assert!((0.5..=2.0).contains(&g), "gain {g}");
            }
        });
    }

    /// The closed-form residual matches the empirical residual measured by
    /// replaying the calibration rows through both layers.
    #[test]
    fn fitted_mse_matches_empirical() {
        let mut rng = Pcg64::new(11);
        let (o, d, rows) = (24, 5, 400);
        // Correlated channels + a mean offset so both g and c matter.
        let basis = gen::matrix(&mut rng, 4, o, 1.0);
        let mut x = vec![0.0f32; rows * o];
        for r in 0..rows {
            let z: Vec<f32> = (0..4).map(|_| rng.normal_f32(0.4, 1.0)).collect();
            for c in 0..o {
                let mut v = 0.3;
                for k in 0..4 {
                    v += z[k] * basis[k * o + c];
                }
                x[r * o + c] = v + rng.normal_f32(0.0, 0.05);
            }
        }
        let (gram, mean) = moments(&x, rows, o);
        let w2 = Tensor::from_vec(&[o, d], gen::matrix(&mut rng, o, d, 1.0));
        let qm = quantize(w2.data(), o, d);
        let corr = fit_dequant_correction(&w2, &qm, &gram, &mean, 1e-6);
        let dq = dequant(&qm);
        let (mut emp_id, mut emp_fit) = (0.0f64, 0.0f64);
        for r in 0..rows {
            let xr = &x[r * o..(r + 1) * o];
            for j in 0..d {
                let t: f64 = (0..o).map(|i| (xr[i] * w2.at2(i, j)) as f64).sum();
                let u: f64 = (0..o).map(|i| (xr[i] * dq[i * d + j]) as f64).sum();
                let e_id = t - u;
                let e_fit = t - (corr.gains[j] as f64 * u + corr.offsets[j] as f64);
                emp_id += e_id * e_id;
                emp_fit += e_fit * e_fit;
            }
        }
        emp_id /= rows as f64;
        emp_fit /= rows as f64;
        assert!((emp_id - corr.mse_identity).abs() <= 0.05 * (1.0 + emp_id), "{emp_id} vs {}", corr.mse_identity);
        assert!((emp_fit - corr.mse_fitted).abs() <= 0.05 * (1.0 + emp_fit), "{emp_fit} vs {}", corr.mse_fitted);
        assert!(emp_fit <= emp_id * (1.0 + 1e-3) + 1e-9);
    }

    /// End-to-end fold on a real store: corrected scales/bias differ from
    /// plain quantization, shapes survive, and the report improves (or
    /// ties) the calibration residual.
    #[test]
    fn corrected_quantize_folds_into_store() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let w = WeightStore::init(cfg, 7);
        // Synthetic calibration moments at the dense hidden width.
        let mut rng = Pcg64::new(5);
        let rows = 64;
        let stats = CalibStats {
            layers: (0..cfg.layers)
                .map(|_| {
                    let mut hidden = MomentAccumulator::new(cfg.mlp);
                    hidden.add_batch(&gen::matrix(&mut rng, rows, cfg.mlp, 1.0), rows);
                    crate::prune::LayerStats {
                        hidden,
                        active: crate::stats::ActiveCounter::new(cfg.mlp, 0.05),
                        q: Tensor::from_vec(&[1, 1, 1, 1], vec![0.0]),
                        k: Tensor::from_vec(&[1, 1, 1, 1], vec![0.0]),
                    }
                })
                .collect(),
            sections: crate::util::timer::Sections::new(),
        };
        let kept: Vec<Vec<usize>> = (0..cfg.layers).map(|_| (0..cfg.mlp).collect()).collect();
        let plain = quantize_weights(cfg, &w).unwrap();
        let (qs, report) = quantize_weights_corrected(cfg, &w, &stats, &kept, 1e-2).unwrap();
        assert_eq!(report.layers_corrected, cfg.layers);
        assert!(report.mse_fitted <= report.mse_identity * (1.0 + 1e-3) + 1e-9);
        // Codes untouched, scales re-folded.
        let (p0, c0) = (
            plain.expect_q("blocks.0.mlp.w2").unwrap(),
            qs.expect_q("blocks.0.mlp.w2").unwrap(),
        );
        assert_eq!(p0.data, c0.data);
        assert_eq!(p0.scales.len(), c0.scales.len());
        // Non-w2 projections keep their plain scales.
        assert_eq!(
            plain.expect_q("blocks.0.attn.wq").unwrap().scales,
            qs.expect_q("blocks.0.attn.wq").unwrap().scales
        );
        // Bias fold kept shape.
        assert_eq!(
            qs.base().expect("blocks.0.mlp.b2").unwrap().shape(),
            plain.base().expect("blocks.0.mlp.b2").unwrap().shape()
        );
    }

    #[test]
    fn kept_indices_identity_when_unpruned() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let w = WeightStore::init(cfg, 1);
        let mut rng = Pcg64::new(9);
        let stats = CalibStats {
            layers: (0..cfg.layers)
                .map(|_| {
                    let mut hidden = MomentAccumulator::new(cfg.mlp);
                    hidden.add_batch(&gen::matrix(&mut rng, 8, cfg.mlp, 1.0), 8);
                    let mut active = crate::stats::ActiveCounter::new(cfg.mlp, 0.05);
                    active.add_batch(&gen::matrix(&mut rng, 8, cfg.mlp, 1.0), 8);
                    crate::prune::LayerStats {
                        hidden,
                        active,
                        q: Tensor::from_vec(&[1, 1, 1, 1], vec![0.0]),
                        k: Tensor::from_vec(&[1, 1, 1, 1], vec![0.0]),
                    }
                })
                .collect(),
            sections: crate::util::timer::Sections::new(),
        };
        let dense_opts = PruneOpts {
            sparsity: crate::model::Sparsity { mlp_s10: 0, attn_s10: 0 },
            ..PruneOpts::default()
        };
        let kept = mlp_kept_indices(cfg, &w, &stats, &dense_opts).unwrap();
        assert_eq!(kept.len(), cfg.layers);
        assert_eq!(kept[0], (0..cfg.mlp).collect::<Vec<_>>());
        // Pruned: kept sets shrink and stay ascending.
        let pruned_opts = PruneOpts {
            sparsity: crate::model::Sparsity { mlp_s10: 5, attn_s10: 0 },
            ..PruneOpts::default()
        };
        let kept = mlp_kept_indices(cfg, &w, &stats, &pruned_opts).unwrap();
        assert!(kept[0].len() < cfg.mlp);
        assert!(kept[0].windows(2).all(|p| p[0] < p[1]));
    }
}
