//! MLP affine compensation (Alg. 3, App. B.1) and the distortion identities
//! of Props. C.1.1 / C.1.2.

use crate::linalg::ridge::ridge_right;
use crate::linalg::{sym_pinv, Mat};
use crate::stats::CovBlocks;
use crate::tensor::Tensor;

/// Result of compensating one MLP block's second linear layer.
pub struct MlpCompensation {
    /// Compensated kept weights Ŵ_S = W_S + W_P B, stored [|S|, d] in the
    /// w2 row-layout (rows are hidden channels).
    pub w2_hat: Tensor,
    /// Compensated bias b̂ = b + W_P c, `[d]`.
    pub b2_hat: Tensor,
    /// ρ²_{W_P}: fraction of pruned-channel variance (in W_P directions)
    /// linearly explained by kept channels (Eq. 65) — a free diagnostic.
    pub rho2: f64,
    /// Predicted optimal distortion J*_D = tr(W_P Σ_{P|S} W_Pᵀ) (Eq. 11).
    pub j_star: f64,
    /// Uncompensated distortion J_uncomp (Eq. 63).
    pub j_uncomp: f64,
}

/// Compensate the second MLP linear layer.
///
/// `w2` `[o, d]` (row i = output contribution of hidden channel i — the
/// *columns* W_{:,i} of the paper's y = Wx view), `b2` `[d]`;
/// `blocks` = covariance blocks of the hidden activations for the
/// (kept, pruned) partition; `lambda` = ridge strength.
///
/// Returns pruned + compensated (Ŵ_S, b̂) plus diagnostics. Rows of `w2_hat`
/// correspond to `kept` in ascending index order.
pub fn compensate_mlp(
    w2: &Tensor,
    b2: &Tensor,
    kept: &[usize],
    pruned: &[usize],
    blocks: &CovBlocks,
    lambda: f64,
) -> MlpCompensation {
    compensate_mlp_opts(w2, b2, kept, pruned, blocks, lambda, true)
}

/// `compensate_mlp` with the distortion diagnostics optional: the ρ²/J*
/// computation needs a pseudo-inverse of Σ_SS (a |S|³·sweeps Jacobi eigen
/// solve) and dominated pipeline time at larger sizes (§Perf L3-2) — the
/// *solve itself* is a single Cholesky. Production pruning passes
/// `diagnostics = false`.
#[allow(clippy::too_many_arguments)]
pub fn compensate_mlp_opts(
    w2: &Tensor,
    b2: &Tensor,
    kept: &[usize],
    pruned: &[usize],
    blocks: &CovBlocks,
    lambda: f64,
    diagnostics: bool,
) -> MlpCompensation {
    let d = w2.shape()[1];
    assert_eq!(b2.shape(), &[d]);
    // W_P as a Mat [d, |P|]: column j = w2 row pruned[j] (paper orientation
    // y = W x has W [d, o]; our storage is the transpose).
    let wp = gather_wt(w2, pruned); // [d, |P|]
    let ws = gather_wt(w2, kept); // [d, |S|]

    // B = Σ_PS (Σ_SS + λI)⁻¹, c = μ_P − B μ_S   (Eq. 9)
    let b_mat = ridge_right(&blocks.ps, &blocks.ss, lambda); // [|P|, |S|]
    let c: Vec<f64> = (0..pruned.len())
        .map(|i| {
            blocks.mu_p[i]
                - (0..kept.len()).map(|j| b_mat.at(i, j) * blocks.mu_s[j]).sum::<f64>()
        })
        .collect();

    // Fold: Ŵ_S = W_S + W_P B  ([d, |S|]), b̂ = b + W_P c.
    let ws_hat = ws.add(&wp.mul(&b_mat));
    let mut b_hat = vec![0.0f64; d];
    for r in 0..d {
        b_hat[r] = b2.data()[r] as f64 + (0..pruned.len()).map(|i| wp.at(r, i) * c[i]).sum::<f64>();
    }

    // Diagnostics (Props. C.1.1 / C.1.2) — optional on the hot path.
    let (j_star, j_uncomp, rho2) =
        if diagnostics { mlp_distortion(&wp, blocks) } else { (0.0, 0.0, 0.0) };

    // Back to w2 row layout: w2_hat [|S|, d] with row k = column k of Ŵ_S.
    let mut w2_hat = vec![0.0f32; kept.len() * d];
    for k in 0..kept.len() {
        for r in 0..d {
            w2_hat[k * d + r] = ws_hat.at(r, k) as f32;
        }
    }
    MlpCompensation {
        w2_hat: Tensor::from_vec(&[kept.len(), d], w2_hat),
        b2_hat: Tensor::from_vec(&[d], b_hat.iter().map(|&v| v as f32).collect()),
        rho2,
        j_star,
        j_uncomp,
    }
}

/// Gather hidden-channel rows of w2 [o, d] into a [d, k] Mat (transposed to
/// the paper's W orientation).
fn gather_wt(w2: &Tensor, idx: &[usize]) -> Mat {
    let d = w2.shape()[1];
    let mut m = Mat::zeros(d, idx.len());
    for (j, &i) in idx.iter().enumerate() {
        let row = w2.row(i);
        for r in 0..d {
            m.set(r, j, row[r] as f64);
        }
    }
    m
}

/// Distortion identities: returns (J*_D, J_uncomp, ρ²_{W_P}).
///
/// J*_D   = tr(W_P Σ_{P|S} W_Pᵀ),  Σ_{P|S} = Σ_PP − Σ_PS Σ_SS† Σ_SP   (Eq. 11)
/// J_unc  = tr(W_P Σ_PP W_Pᵀ) + ‖W_P μ_P‖²                            (Eq. 63)
/// ρ²     = tr(W_P Σ_PS Σ_SS† Σ_SP W_Pᵀ) / tr(W_P Σ_PP W_Pᵀ)          (Eq. 65)
pub fn mlp_distortion(wp: &Mat, blocks: &CovBlocks) -> (f64, f64, f64) {
    if wp.c == 0 {
        return (0.0, 0.0, 0.0);
    }
    let ss_pinv = sym_pinv(&blocks.ss, 1e-10);
    let explained = blocks.ps.mul(&ss_pinv).mul(&blocks.ps.t()); // Σ_PS Σ_SS† Σ_SP
    let sigma_cond = blocks.pp.sub(&explained);
    let j_star = trace_wswt(wp, &sigma_cond).max(0.0);
    let var_term = trace_wswt(wp, &blocks.pp);
    // ‖W_P μ_P‖²
    let mut mean_term = 0.0;
    for r in 0..wp.r {
        let mut s = 0.0;
        for i in 0..wp.c {
            s += wp.at(r, i) * blocks.mu_p[i];
        }
        mean_term += s * s;
    }
    let j_uncomp = var_term + mean_term;
    let rho2 = if var_term > 0.0 {
        (trace_wswt(wp, &explained) / var_term).clamp(0.0, 1.0)
    } else {
        0.0
    };
    (j_star, j_uncomp, rho2)
}

/// tr(W S Wᵀ) for W [d, k], S [k, k].
fn trace_wswt(w: &Mat, s: &Mat) -> f64 {
    let ws = w.mul(s);
    let mut tr = 0.0;
    for r in 0..w.r {
        for i in 0..w.c {
            tr += ws.at(r, i) * w.at(r, i);
        }
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{cov_blocks, MomentAccumulator};
    use crate::util::prop::{gen, run_prop};
    use crate::util::Pcg64;

    /// Build synthetic activations where pruned channels are exact affine
    /// functions of kept ones: compensation must be (near) lossless.
    #[test]
    fn lossless_when_pruned_is_affine_of_kept() {
        let mut rng = Pcg64::new(3);
        let (s_n, p_n, d, rows) = (5, 3, 4, 400);
        let o = s_n + p_n;
        let b_true = gen::matrix(&mut rng, p_n, s_n, 0.7);
        let c_true: Vec<f32> = (0..p_n).map(|_| rng.normal_f32(0.5, 0.3)).collect();
        // Activations: kept random; pruned = B xS + c (no noise).
        let mut x = vec![0.0f32; rows * o];
        for r in 0..rows {
            for j in 0..s_n {
                x[r * o + j] = rng.normal_f32(0.3, 1.0);
            }
            for i in 0..p_n {
                let mut v = c_true[i];
                for j in 0..s_n {
                    v += b_true[i * s_n + j] * x[r * o + j];
                }
                x[r * o + s_n + i] = v;
            }
        }
        let mut acc = MomentAccumulator::new(o);
        acc.add_batch(&x, rows);
        let cov = acc.covariance();
        let mean = acc.mean();
        let kept: Vec<usize> = (0..s_n).collect();
        let pruned: Vec<usize> = (s_n..o).collect();
        let blocks = cov_blocks(&cov, &mean, &kept, &pruned);
        let w2 = Tensor::from_vec(&[o, d], gen::matrix(&mut rng, o, d, 0.5));
        let b2 = Tensor::from_vec(&[d], vec![0.1; d]);
        let comp = compensate_mlp(&w2, &b2, &kept, &pruned, &blocks, 1e-8);

        // Validate on fresh samples from the same process: y_full == y_comp.
        let mut max_err = 0.0f64;
        for _ in 0..50 {
            let mut xs = vec![0.0f32; o];
            for j in 0..s_n {
                xs[j] = rng.normal_f32(0.3, 1.0);
            }
            for i in 0..p_n {
                let mut v = c_true[i];
                for j in 0..s_n {
                    v += b_true[i * s_n + j] * xs[j];
                }
                xs[s_n + i] = v;
            }
            for col in 0..d {
                let full: f64 = (0..o).map(|i| (xs[i] * w2.at2(i, col)) as f64).sum::<f64>()
                    + b2.data()[col] as f64;
                let compv: f64 = (0..s_n)
                    .map(|k| (xs[kept[k]] * comp.w2_hat.at2(k, col)) as f64)
                    .sum::<f64>()
                    + comp.b2_hat.data()[col] as f64;
                max_err = max_err.max((full - compv).abs());
            }
        }
        assert!(max_err < 1e-3, "max_err={max_err}");
        assert!(comp.rho2 > 0.99, "rho2={}", comp.rho2);
        assert!(comp.j_star < 1e-4 * comp.j_uncomp.max(1e-12));
    }

    /// The closed-form distortion (Eq. 11) must match the empirical layer
    /// error measured on the calibration data itself.
    #[test]
    fn distortion_identity_matches_empirical() {
        run_prop("mlp.distortion identity", 8, |rng| {
            let o = 4 + rng.below(6);
            let d = 2 + rng.below(4);
            let rows = 300;
            let x = gen::matrix(rng, rows, o, 1.0);
            let mut acc = MomentAccumulator::new(o);
            acc.add_batch(&x, rows);
            let cov = acc.covariance();
            let mean = acc.mean();
            let k = 1 + rng.below(o - 1);
            let kept: Vec<usize> = (0..k).collect();
            let pruned: Vec<usize> = (k..o).collect();
            let blocks = cov_blocks(&cov, &mean, &kept, &pruned);
            let w2 = Tensor::from_vec(&[o, d], gen::matrix(rng, o, d, 1.0));
            let b2 = Tensor::from_vec(&[d], vec![0.0; d]);
            let comp = compensate_mlp(&w2, &b2, &kept, &pruned, &blocks, 1e-9);
            // Empirical error of the compensated layer on the calibration set.
            let mut emp = 0.0f64;
            for r in 0..rows {
                let xr = &x[r * o..(r + 1) * o];
                for col in 0..d {
                    let full: f64 = (0..o).map(|i| (xr[i] * w2.at2(i, col)) as f64).sum();
                    let cv: f64 = (0..k)
                        .map(|j| (xr[kept[j]] * comp.w2_hat.at2(j, col)) as f64)
                        .sum::<f64>()
                        + comp.b2_hat.data()[col] as f64
                        - b2.data()[col] as f64;
                    let e = full - cv;
                    emp += e * e;
                }
            }
            emp /= rows as f64;
            // J* from the identity (λ→0 limit; small λ used in solve).
            let rel = (emp - comp.j_star).abs() / (1.0 + comp.j_star);
            assert!(rel < 0.05, "emp={emp} j_star={} rel={rel}", comp.j_star);
        });
    }

    /// Compensation gain is non-negative: J_uncomp >= J* (Prop. C.1.2).
    #[test]
    fn gain_nonnegative_prop() {
        run_prop("mlp.gain >= 0", 10, |rng| {
            let o = 3 + rng.below(8);
            let d = 1 + rng.below(4);
            let rows = 120;
            let x = gen::matrix(rng, rows, o, 1.0);
            let mut acc = MomentAccumulator::new(o);
            acc.add_batch(&x, rows);
            let k = 1 + rng.below(o - 1);
            let kept: Vec<usize> = (0..k).collect();
            let pruned: Vec<usize> = (k..o).collect();
            let blocks = cov_blocks(&acc.covariance(), &acc.mean(), &kept, &pruned);
            let w2 = Tensor::from_vec(&[o, d], gen::matrix(rng, o, d, 1.0));
            let wp = super::gather_wt(&w2, &pruned);
            let (j_star, j_uncomp, rho2) = mlp_distortion(&wp, &blocks);
            assert!(j_uncomp >= j_star - 1e-9 * j_uncomp.abs());
            assert!((0.0..=1.0).contains(&rho2));
        });
    }

    #[test]
    fn empty_prune_set_is_identity() {
        let o = 4;
        let d = 3;
        let mut rng = Pcg64::new(5);
        let x = gen::matrix(&mut rng, 50, o, 1.0);
        let mut acc = MomentAccumulator::new(o);
        acc.add_batch(&x, 50);
        let kept: Vec<usize> = (0..o).collect();
        let pruned: Vec<usize> = vec![];
        let blocks = cov_blocks(&acc.covariance(), &acc.mean(), &kept, &pruned);
        let w2 = Tensor::from_vec(&[o, d], gen::matrix(&mut rng, o, d, 1.0));
        let b2 = Tensor::from_vec(&[d], vec![0.5; d]);
        let comp = compensate_mlp(&w2, &b2, &kept, &pruned, &blocks, 1e-6);
        assert!(comp.w2_hat.max_abs_diff(&w2) < 1e-6);
        assert!(comp.b2_hat.max_abs_diff(&b2) < 1e-6);
        assert_eq!(comp.j_star, 0.0);
    }
}
