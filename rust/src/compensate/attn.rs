//! Attention Q/K logit compensation (Alg. 5, App. B.2).
//!
//! Per layer and head: accumulate the Kronecker ridge system over
//! calibration samples, solve for M, factor I + M = U Σ Vᵀ, and fold
//! U Σ^{1/2} / V Σ^{1/2} into the kept query/key projection columns (and
//! biases — Q̂_S = Q_S P means b̂_q = Pᵀ b_{q,S}).

use crate::linalg::kron::KronRidge;
use crate::linalg::svd::sqrt_split;
use crate::linalg::Mat;
use crate::tensor::Tensor;

/// Compensated per-head projections + diagnostics.
pub struct AttnCompensation {
    /// New kept-dim query projection block [d, d'].
    pub wq: Mat,
    /// New query bias [d'].
    pub bq: Vec<f64>,
    /// New kept-dim key projection block [d, d'].
    pub wk: Mat,
    /// New key bias [d'].
    pub bk: Vec<f64>,
    /// Compensation gain hᵀ(G+λI)⁻¹h ≥ 0 (Prop. C.2.2).
    pub gain: f64,
    /// Bilinear R²: gain / Σ‖T_b‖² (Eq. 93).
    pub rho2: f64,
    /// Uncompensated logit energy Σ_b ‖Q_P K_Pᵀ‖²_F.
    pub t_energy: f64,
}

/// Gather columns `idx` of a per-sample activation slab.
/// `qk`: [B, n, dh] row-major; returns per-sample [n, |idx|] matrices.
fn sample_mat(qk: &Tensor, sample: usize, idx: &[usize]) -> Mat {
    let shape = qk.shape();
    let (n, dh) = (shape[1], shape[2]);
    let mut m = Mat::zeros(n, idx.len());
    let base = sample * n * dh;
    for t in 0..n {
        for (j, &c) in idx.iter().enumerate() {
            m.set(t, j, qk.data()[base + t * dh + c] as f64);
        }
    }
    m
}

/// Compensate one attention head.
///
/// * `q`, `k`: captured dense per-head activations `[B, n, dh]`;
/// * `kept` / `pruned`: dh-index partition from Alg. 4;
/// * `wq_head`, `wk_head`: dense projection blocks `[d, dh]` for this head;
/// * `bq_head`, `bk_head`: dense biases `[dh]`;
/// * `lambda`: ridge strength;
/// * `max_samples`: cap on calibration samples for the Kronecker
///   accumulation (the compensator has only d'² parameters — Prop. C.2.3's
///   d'²/N rate — so a modest cap loses nothing and bounds the d'⁴ cost).
#[allow(clippy::too_many_arguments)]
pub fn compensate_attn_head(
    q: &Tensor,
    k: &Tensor,
    kept: &[usize],
    pruned: &[usize],
    wq_head: &Mat,
    bq_head: &[f64],
    wk_head: &Mat,
    bk_head: &[f64],
    lambda: f64,
    max_samples: usize,
) -> AttnCompensation {
    let dp = kept.len();
    let b_total = q.shape()[0].min(max_samples);

    // Kept-column projections (pre-compensation).
    let wq_s = gather_cols(wq_head, kept);
    let wk_s = gather_cols(wk_head, kept);
    let bq_s: Vec<f64> = kept.iter().map(|&i| bq_head[i]).collect();
    let bk_s: Vec<f64> = kept.iter().map(|&i| bk_head[i]).collect();

    if pruned.is_empty() {
        return AttnCompensation {
            wq: wq_s,
            bq: bq_s,
            wk: wk_s,
            bk: bk_s,
            gain: 0.0,
            rho2: 0.0,
            t_energy: 0.0,
        };
    }

    // Accumulate the per-head Kronecker ridge system (Eq. 15).
    let mut acc = KronRidge::new(dp);
    for b in 0..b_total {
        let qs = sample_mat(q, b, kept);
        let qp = sample_mat(q, b, pruned);
        let ks = sample_mat(k, b, kept);
        let kp = sample_mat(k, b, pruned);
        let kk = ks.t().mul(&ks);
        let qq = qs.t().mul(&qs);
        let r = qs.t().mul(&qp).mul(&kp.t().mul(&ks));
        // ‖Q_P K_Pᵀ‖²_F = tr((Q_PᵀQ_P)(K_PᵀK_P)) — no n×n materialization.
        let qqp = qp.t().mul(&qp);
        let kkp = kp.t().mul(&kp);
        let t_sq = qqp.mul(&kkp).trace();
        acc.accumulate(&kk, &qq, &r, t_sq);
    }
    let m = acc.solve(lambda);
    let (gain, rho2) = acc.gain_and_rho2(lambda);

    // Fold I + M = U Σ Vᵀ into the projections (Eq. 16).
    let i_plus_m = Mat::eye(dp).add(&m);
    let (p, qfac) = sqrt_split(&i_plus_m); // P Qᵀ = I + M
    let wq_new = wq_s.mul(&p);
    let wk_new = wk_s.mul(&qfac);
    let bq_new = vec_mat(&bq_s, &p);
    let bk_new = vec_mat(&bk_s, &qfac);

    AttnCompensation {
        wq: wq_new,
        bq: bq_new,
        wk: wk_new,
        bk: bk_new,
        gain,
        rho2,
        t_energy: acc.t_energy,
    }
}

fn gather_cols(m: &Mat, idx: &[usize]) -> Mat {
    let mut out = Mat::zeros(m.r, idx.len());
    for r in 0..m.r {
        for (j, &c) in idx.iter().enumerate() {
            out.set(r, j, m.at(r, c));
        }
    }
    out
}

/// vᵀ P as a vector (bias transform).
fn vec_mat(v: &[f64], p: &Mat) -> Vec<f64> {
    assert_eq!(v.len(), p.r);
    (0..p.c).map(|j| (0..p.r).map(|i| v[i] * p.at(i, j)).sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::gen;
    use crate::util::Pcg64;

    /// Build Q/K activations whose pruned-dim logits are exactly
    /// representable in the kept bilinear span — compensation must recover
    /// the full logits through the folded projections.
    #[test]
    fn folded_projections_recover_logits() {
        let mut rng = Pcg64::new(8);
        let (d, dh, n, bsz) = (10, 6, 7, 24);
        let kept: Vec<usize> = vec![0, 1, 2, 3];
        let pruned: Vec<usize> = vec![4, 5];
        // Projections.
        let wq = Mat::from_f32(d, dh, &gen::matrix(&mut rng, d, dh, 0.5));
        let wk = Mat::from_f32(d, dh, &gen::matrix(&mut rng, d, dh, 0.5));
        let bq = vec![0.1; dh];
        let bk = vec![-0.05; dh];
        // Inputs and captured Q/K = XW + b.
        let mut qdata = vec![0.0f32; bsz * n * dh];
        let mut kdata = vec![0.0f32; bsz * n * dh];
        let mut xs = Vec::new();
        for b in 0..bsz {
            let x = Mat::from_f32(n, d, &gen::matrix(&mut rng, n, d, 1.0));
            for t in 0..n {
                for j in 0..dh {
                    let mut qv = bq[j];
                    let mut kv = bk[j];
                    for c in 0..d {
                        qv += x.at(t, c) * wq.at(c, j);
                        kv += x.at(t, c) * wk.at(c, j);
                    }
                    qdata[(b * n + t) * dh + j] = qv as f32;
                    kdata[(b * n + t) * dh + j] = kv as f32;
                }
            }
            xs.push(x);
        }
        let q = Tensor::from_vec(&[bsz, n, dh], qdata);
        let k = Tensor::from_vec(&[bsz, n, dh], kdata);
        let comp = compensate_attn_head(&q, &k, &kept, &pruned, &wq, &bq, &wk, &bk, 1e-6, bsz);

        // Measure total logit error with and without compensation on the
        // calibration samples.
        let mut err_comp = 0.0f64;
        let mut err_naive = 0.0f64;
        let mut total = 0.0f64;
        for (b, x) in xs.iter().enumerate() {
            // Full logits.
            let qfull = x.mul(&wq).add(&row_bias(n, &bq));
            let kfull = x.mul(&wk).add(&row_bias(n, &bk));
            let l_full = qfull.mul(&kfull.t());
            // Compensated kept logits.
            let qc = x.mul(&comp.wq).add(&row_bias(n, &comp.bq));
            let kc = x.mul(&comp.wk).add(&row_bias(n, &comp.bk));
            let l_comp = qc.mul(&kc.t());
            // Naive kept logits.
            let qs = sample_mat(&q, b, &kept);
            let ks = sample_mat(&k, b, &kept);
            let l_naive = qs.mul(&ks.t());
            err_comp += l_full.sub(&l_comp).frob().powi(2);
            err_naive += l_full.sub(&l_naive).frob().powi(2);
            total += l_full.frob().powi(2);
        }
        assert!(err_comp < err_naive * 0.9, "comp {err_comp} vs naive {err_naive}");
        assert!(err_comp / total < 0.5);
        assert!(comp.gain > 0.0);
        assert!((0.0..=1.0).contains(&comp.rho2));
    }

    fn row_bias(n: usize, b: &[f64]) -> Mat {
        let mut m = Mat::zeros(n, b.len());
        for t in 0..n {
            for j in 0..b.len() {
                m.set(t, j, b[j]);
            }
        }
        m
    }

    #[test]
    fn no_pruning_returns_kept_projections() {
        let mut rng = Pcg64::new(3);
        let (d, dh, n, bsz) = (4, 3, 5, 4);
        let wq = Mat::from_f32(d, dh, &gen::matrix(&mut rng, d, dh, 1.0));
        let wk = Mat::from_f32(d, dh, &gen::matrix(&mut rng, d, dh, 1.0));
        let q = Tensor::from_vec(&[bsz, n, dh], gen::matrix(&mut rng, bsz * n, dh, 1.0));
        let k = Tensor::from_vec(&[bsz, n, dh], gen::matrix(&mut rng, bsz * n, dh, 1.0));
        let kept: Vec<usize> = (0..dh).collect();
        let comp = compensate_attn_head(&q, &k, &kept, &[], &wq, &[0.0; 3], &wk, &[0.0; 3], 1e-6, bsz);
        assert!(comp.wq.max_abs_diff(&wq) < 1e-12);
        assert_eq!(comp.gain, 0.0);
    }

    #[test]
    fn bias_transform_orientation() {
        // vᵀP with P = 2I doubles the bias.
        let p = Mat::eye(3).scale(2.0);
        let out = vec_mat(&[1.0, 2.0, 3.0], &p);
        assert_eq!(out, vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn t_energy_positive_when_pruning() {
        let mut rng = Pcg64::new(9);
        let (d, dh, n, bsz) = (6, 4, 5, 8);
        let wq = Mat::from_f32(d, dh, &gen::matrix(&mut rng, d, dh, 1.0));
        let wk = Mat::from_f32(d, dh, &gen::matrix(&mut rng, d, dh, 1.0));
        let q = Tensor::from_vec(&[bsz, n, dh], gen::matrix(&mut rng, bsz * n, dh, 1.0));
        let k = Tensor::from_vec(&[bsz, n, dh], gen::matrix(&mut rng, bsz * n, dh, 1.0));
        let comp = compensate_attn_head(
            &q, &k, &[0, 1], &[2, 3], &wq, &[0.0; 4], &wk, &[0.0; 4], 1e-4, bsz,
        );
        assert!(comp.t_energy > 0.0);
        assert_eq!(comp.wq.c, 2);
        assert_eq!(comp.bq.len(), 2);
    }
}
