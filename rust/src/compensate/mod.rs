//! Closed-form compensation (the core of CORP, §3.4 + App. B).
//!
//! * [`mlp`]: affine compensator x_P ≈ B x_S + c folded into the second
//!   linear layer: Ŵ_S = W_S + W_P B, b̂ = b + W_P c (Alg. 3).
//! * [`attn`]: logit compensator Q_P K_Pᵀ ≈ Q_S M K_Sᵀ solved per head from
//!   the Kronecker ridge system and folded into the Q/K projections via the
//!   SVD of I + M (Alg. 5).
//!
//! Both expose the paper's exact distortion diagnostics (Props. C.1.1–C.2.2)
//! which the test-suite checks against brute-force objectives.

pub mod mlp;
pub mod attn;
pub mod quant;

pub use attn::{compensate_attn_head, AttnCompensation};
pub use mlp::{compensate_mlp, mlp_distortion, MlpCompensation};
pub use quant::{
    fit_dequant_correction, mlp_kept_indices, quantize_weights, quantize_weights_corrected,
    QuantCorrection, QuantReport,
};
