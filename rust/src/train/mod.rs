//! Training loop — drives the AOT `train_*` step graph from Rust.
//!
//! This is how the "pretrained" checkpoints of the paper's protocol are
//! produced in a world with no downloads: deterministic init + a few hundred
//! SGD steps on the synthetic corpus, executed entirely through PJRT. The
//! loss curve is logged (EXPERIMENTS.md §E2E) and checkpoints are cached
//! under `artifacts/ckpt/` so repeated runs never retrain.

use anyhow::{Context, Result};

use crate::data::{Split, TextGen, VisionGen};
use crate::info;
use crate::model::{ModelConfig, ModelKind, WeightStore};
use crate::runtime::{Input, Runtime};
use crate::tensor::Tensor;
use crate::util::Stopwatch;

/// Steps per train-chunk artifact call (must match aot.py TRAIN_CHUNK).
pub const CHUNK: usize = 20;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f32,
    /// Linear warmup steps.
    pub warmup: usize,
    /// Cosine decay to this fraction of lr.
    pub final_lr_frac: f32,
    pub seed: u64,
    /// Log every k steps.
    pub log_every: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self { steps: 300, lr: 1e-3, warmup: 30, final_lr_frac: 0.1, seed: 17, log_every: 50 }
    }
}

/// A recorded training run.
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub wall_secs: f64,
}

fn lr_at(opts: &TrainOpts, step: usize) -> f32 {
    if step < opts.warmup {
        return opts.lr * (step + 1) as f32 / opts.warmup as f32;
    }
    let t = (step - opts.warmup) as f32 / (opts.steps - opts.warmup).max(1) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
    opts.lr * (opts.final_lr_frac + (1.0 - opts.final_lr_frac) * cos)
}

/// Train `cfg` from `init` via the AOT train-step artifact; returns the
/// trained weights and the loss curve.
pub fn train(
    rt: &Runtime,
    cfg: &'static ModelConfig,
    mut weights: WeightStore,
    opts: &TrainOpts,
) -> Result<(WeightStore, TrainLog)> {
    let art = cfg.train_artifact();
    let spec = cfg.param_spec();
    let batch = cfg.eval_batch();
    // Adam state.
    let zeros = |ws: &WeightStore| -> Vec<Tensor> {
        spec.iter().map(|(n, _)| Tensor::zeros(ws.expect(n).unwrap().shape())).collect()
    };
    let mut m_state: Vec<Tensor> = zeros(&weights);
    let mut v_state: Vec<Tensor> = zeros(&weights);
    let vision = VisionGen::new(crate::data::DATA_SEED);
    let text = TextGen::new(crate::data::DATA_SEED);
    let sw = Stopwatch::start();
    let mut losses = Vec::with_capacity(opts.steps);

    // Chunked loop: CHUNK steps per PJRT call (params/optimizer state stay
    // on the device side of the call; see aot.py TRAIN_CHUNK and §Perf L3-1).
    let chunks = opts.steps.div_ceil(CHUNK);
    for chunk in 0..chunks {
        let step0 = chunk * CHUNK;
        // Per-step data for the whole chunk, stacked on a leading K axis.
        let mut tok_slab: Vec<f32> = Vec::new();
        let mut id_slab: Vec<i32> = Vec::new();
        let mut label_slab: Vec<i32> = Vec::new();
        let mut lrs: Vec<f32> = Vec::with_capacity(CHUNK);
        for i in 0..CHUNK {
            let step = step0 + i;
            match cfg.kind {
                ModelKind::Vit => {
                    let (t, l) = vision.batch(Split::Train, step as u64, batch);
                    tok_slab.extend_from_slice(t.data());
                    label_slab.extend_from_slice(&l);
                }
                ModelKind::Gpt => {
                    let (ids, l) = text.batch(Split::Train, step as u64, batch, cfg.n_ctx);
                    id_slab.extend_from_slice(&ids);
                    label_slab.extend_from_slice(&l);
                }
            }
            lrs.push(lr_at(opts, step.min(opts.steps - 1)));
        }
        let mut inputs: Vec<Input> = Vec::with_capacity(4 + 3 * spec.len());
        let tok_tensor;
        match cfg.kind {
            ModelKind::Vit => {
                tok_tensor =
                    Tensor::from_vec(&[CHUNK, batch, cfg.patches, cfg.patch_dim], tok_slab);
                inputs.push(Input::F32(&tok_tensor));
                inputs.push(Input::I32(&label_slab, vec![CHUNK, batch]));
            }
            ModelKind::Gpt => {
                inputs.push(Input::I32(&id_slab, vec![CHUNK, batch, cfg.n_ctx]));
                inputs.push(Input::I32(&label_slab, vec![CHUNK, batch, cfg.n_ctx]));
            }
        }
        let lrs_tensor = Tensor::from_vec(&[CHUNK], lrs);
        inputs.push(Input::F32(&lrs_tensor));
        inputs.push(Input::Scalar((step0 + 1) as f32)); // Adam t at chunk start
        for (n, _) in &spec {
            inputs.push(Input::F32(weights.expect(n)?));
        }
        for t in m_state.iter().chain(&v_state) {
            inputs.push(Input::F32(t));
        }
        let mut out = rt.execute(&art, &inputs).context("train chunk")?;
        let chunk_losses = out.pop().context("train chunk returned nothing")?;
        // Outputs: params..., adam_m..., adam_v... (losses already popped).
        let n = spec.len();
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        for ((name, _), t) in spec.iter().zip(out) {
            weights.insert(name.clone(), t);
        }
        m_state = new_m;
        v_state = new_v;
        losses.extend_from_slice(chunk_losses.data());
        let last = *losses.last().unwrap();
        if (step0 / CHUNK) % (opts.log_every.div_ceil(CHUNK)).max(1) == 0 || chunk + 1 == chunks {
            info!(
                "train {} step {}/{} loss {last:.4} lr {:.4}",
                cfg.name,
                (step0 + CHUNK).min(chunks * CHUNK),
                chunks * CHUNK,
                lr_at(opts, step0)
            );
        }
        if !last.is_finite() {
            anyhow::bail!("training diverged near step {step0} (loss={last})");
        }
    }
    losses.truncate(chunks * CHUNK);
    Ok((weights, TrainLog { losses, wall_secs: sw.secs() }))
}

/// Checkpoint path for a (config, steps, seed) triple.
pub fn ckpt_path(cfg: &ModelConfig, opts: &TrainOpts) -> std::path::PathBuf {
    crate::runtime::default_artifacts_dir()
        .join("ckpt")
        .join(format!("{}_s{}_lr{}_seed{}.corpw", cfg.name, opts.steps, opts.lr, opts.seed))
}

/// Load the cached checkpoint or train one (and cache it). Also writes the
/// loss curve CSV to results/ the first time.
pub fn ensure_checkpoint(
    rt: &Runtime,
    cfg: &'static ModelConfig,
    opts: &TrainOpts,
) -> Result<WeightStore> {
    let path = ckpt_path(cfg, opts);
    if path.exists() {
        let w = WeightStore::load(&path)?;
        w.validate_dense(cfg)?;
        return Ok(w);
    }
    info!("no checkpoint for {}; training {} steps", cfg.name, opts.steps);
    let init = WeightStore::init(cfg, opts.seed);
    let (trained, log) = train(rt, cfg, init, opts)?;
    trained.save(&path)?;
    // Persist the loss curve for EXPERIMENTS.md.
    let mut csv = crate::util::bench::CsvWriter::new(&format!("losscurve_{}", cfg.name), "step,loss");
    for (i, l) in log.losses.iter().enumerate() {
        csv.row(&[i.to_string(), format!("{l}")]);
    }
    let _ = csv.flush();
    info!("trained {} in {:.1}s; final loss {:.4}", cfg.name, log.wall_secs, log.losses.last().unwrap());
    Ok(trained)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let opts = TrainOpts { steps: 100, lr: 1.0, warmup: 10, final_lr_frac: 0.1, ..Default::default() };
        assert!(lr_at(&opts, 0) < 0.2); // warmup start
        assert!((lr_at(&opts, 9) - 1.0).abs() < 1e-6); // warmup end
        assert!(lr_at(&opts, 99) < 0.2); // decayed
        // Monotone decay after warmup.
        let mut prev = f32::MAX;
        for s in 10..100 {
            let l = lr_at(&opts, s);
            assert!(l <= prev + 1e-6);
            prev = l;
        }
    }

    #[test]
    fn ckpt_path_encodes_hparams() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let a = ckpt_path(cfg, &TrainOpts::default());
        let b = ckpt_path(cfg, &TrainOpts { steps: 7, ..Default::default() });
        assert_ne!(a, b);
        assert!(a.to_str().unwrap().contains("vit_t"));
    }
}
