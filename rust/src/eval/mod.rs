//! Evaluation harness: top-1 accuracy, perplexity, dense-task metrics.

use anyhow::Result;

use crate::data::{Split, TextGen, VisionGen};
use crate::exec::Executor;
use crate::model::{ModelKind, WeightStore};

/// Top-1 accuracy of a (possibly pruned) ViT over `n_batches` eval batches.
pub fn top1(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &VisionGen,
    n_batches: usize,
) -> Result<f64> {
    top1_from(exec, w, gen, n_batches, 0)
}

/// Map an evaluation seed to the starting eval-batch index of its window.
/// Windows are spaced by a large odd stride so distinct seeds never overlap
/// for any realistic batch count; every variant scored under one seed must
/// use the same window or accuracy deltas pick up eval-sampling noise.
pub fn eval_window(seed: u64) -> u64 {
    seed.wrapping_mul(0x10001)
}

/// [`top1`] over eval batches `start .. start + n_batches` — the `start`
/// offset selects a disjoint eval stream per evaluation seed (see
/// `Coordinator::top1` and [`eval_window`]).
pub fn top1_from(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &VisionGen,
    n_batches: usize,
    start: u64,
) -> Result<f64> {
    assert_eq!(exec.cfg.kind, ModelKind::Vit);
    let b = exec.cfg.eval_batch();
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..n_batches {
        let (tokens, labels) = gen.batch(Split::Eval, start + i as u64, b);
        let logits = exec.forward_vit(w, &tokens, b)?;
        let c = exec.cfg.classes;
        for (j, &label) in labels.iter().enumerate() {
            let row = &logits.data()[j * c..(j + 1) * c];
            let mut best = 0usize;
            for k in 1..c {
                if row[k] > row[best] {
                    best = k;
                }
            }
            if best == label as usize {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(100.0 * correct as f64 / total as f64)
}

/// Perplexity of a *dense* GPT via the evloss artifact.
///
/// Note: the evloss graph carries the full dense parameter spec, so it is
/// only valid for dense weights; pruned GPT perplexity uses `ppl_stitched`.
pub fn ppl_dense(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &TextGen,
    n_batches: usize,
) -> Result<f64> {
    assert_eq!(exec.cfg.kind, ModelKind::Gpt);
    let b = exec.cfg.eval_batch();
    let mut total = 0.0f64;
    for i in 0..n_batches {
        let (ids, targets) = gen.batch(Split::Eval, i as u64, b, exec.cfg.n_ctx);
        let loss = exec.eval_loss(w, None, Some(&ids), &targets)?;
        total += loss as f64;
    }
    Ok((total / n_batches as f64).exp())
}

/// Perplexity via the stitched per-block forward (works for pruned weights):
/// cross-entropy computed in Rust from the head logits.
pub fn ppl_stitched(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &TextGen,
    n_batches: usize,
) -> Result<f64> {
    assert_eq!(exec.cfg.kind, ModelKind::Gpt);
    let b = exec.cfg.eval_batch();
    let n = exec.cfg.n_ctx;
    let v = exec.cfg.vocab;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for i in 0..n_batches {
        let (ids, targets) = gen.batch(Split::Eval, i as u64, b, n);
        let logits = exec.forward_gpt(w, &ids, b)?;
        let data = logits.data();
        for row in 0..b * n {
            let lr = &data[row * v..(row + 1) * v];
            // log-softmax pick
            let m = lr.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f32 = lr.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
            let t = targets[row] as usize;
            total += (lse - lr[t]) as f64;
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}

#[cfg(test)]
mod tests {
    // Covered by the integration tests in rust/tests/ (requires artifacts).
}
