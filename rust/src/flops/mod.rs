//! Analytic parameter and FLOPs accounting (the Params / FLOPs / ↓ columns
//! of Tables 2, 5, 7, 10).
//!
//! FLOPs count multiply–adds as 2 ops, per forward pass of one example, for
//! the exact pruned shapes the runtime executes.

use crate::model::{LayerDims, ModelConfig, ModelKind, Sparsity};

/// Total parameter count at a sparsity setting.
pub fn params(cfg: &ModelConfig, sp: Sparsity) -> usize {
    let (dqk, o) = cfg.pruned_dims(sp);
    let embed: usize =
        cfg.embed_param_spec().iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    let per_block: usize =
        cfg.block_param_spec(dqk, o).iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    let head: usize =
        cfg.head_param_spec().iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    embed + per_block * cfg.layers + head
}

/// Total parameter count at explicit per-layer dims.
pub fn params_layered(cfg: &ModelConfig, dims: &LayerDims) -> usize {
    cfg.param_spec_layered(dims).iter().map(|(_, s)| s.iter().product::<usize>()).sum()
}

/// Forward FLOPs of one transformer block at pruned dims `(dqk, o)`.
fn block_flops(cfg: &ModelConfig, dqk: usize, o: usize) -> usize {
    let (n, d, h, dh) = (cfg.n_ctx, cfg.d, cfg.heads, cfg.dh());
    let mut blk = 0usize;
    blk += 2 * n * d * (h * dqk) * 2; // Q, K projections
    blk += 2 * n * d * (h * dh); // V projection
    blk += 2 * n * n * (h * dqk); // QKᵀ logits
    blk += 2 * n * n * (h * dh); // PV
    blk += 2 * n * (h * dh) * d; // output projection
    blk += 2 * n * d * o * 2; // MLP in + out
    blk += 8 * n * d + 5 * n * o; // layernorms + GELU (approximate elementwise)
    blk
}

/// Embedding + head FLOPs (independent of pruned dims).
fn fixed_flops(cfg: &ModelConfig) -> usize {
    let (n, d) = (cfg.n_ctx, cfg.d);
    let embed = match cfg.kind {
        ModelKind::Vit => 2 * cfg.patches * cfg.patch_dim * d,
        // one-hot matmul is a gather in practice; count the gather-free cost
        // of the d-dim add + pos add only.
        ModelKind::Gpt => 2 * n * d,
    };
    let head = match cfg.kind {
        ModelKind::Vit => 2 * d * cfg.classes,
        ModelKind::Gpt => 2 * n * d * cfg.vocab,
    };
    embed + head
}

/// Forward FLOPs for one example at a sparsity setting.
pub fn flops(cfg: &ModelConfig, sp: Sparsity) -> usize {
    let (dqk, o) = cfg.pruned_dims(sp);
    fixed_flops(cfg) + block_flops(cfg, dqk, o) * cfg.layers
}

/// Forward FLOPs for one example at explicit per-layer dims — the cost the
/// global-budget allocator is measured against.
pub fn flops_layered(cfg: &ModelConfig, dims: &LayerDims) -> usize {
    assert_eq!(dims.dqk.len(), cfg.layers);
    assert_eq!(dims.o.len(), cfg.layers);
    fixed_flops(cfg)
        + dims
            .dqk
            .iter()
            .zip(&dims.o)
            .map(|(&dqk, &o)| block_flops(cfg, dqk, o))
            .sum::<usize>()
}

/// Marginal FLOPs of one MLP hidden unit in any block: ∂(block FLOPs)/∂o.
/// The allocator's cost for removing one hidden channel from one layer.
pub fn mlp_unit_flops(cfg: &ModelConfig) -> usize {
    let (n, d) = (cfg.n_ctx, cfg.d);
    4 * n * d + 5 * n
}

/// Marginal FLOPs of one per-head QK dim in any block: ∂(block FLOPs)/∂dqk.
/// Removing one QK dim drops it from *every* head of the layer at once
/// (the fused `[d, h·dqk]` layout keeps heads uniform), so the unit spans
/// all `h` heads.
pub fn qk_unit_flops(cfg: &ModelConfig) -> usize {
    let (n, d, h) = (cfg.n_ctx, cfg.d, cfg.heads);
    4 * n * d * h + 2 * n * n * h
}

/// Percentage reduction of `pruned` relative to `dense`.
pub fn reduction_pct(dense: usize, pruned: usize) -> f64 {
    if dense == 0 {
        return 0.0;
    }
    100.0 * (1.0 - pruned as f64 / dense as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Scope};

    #[test]
    fn params_match_weight_store() {
        for name in ["vit_t", "vit_b", "gpt_s"] {
            let cfg = ModelConfig::by_name(name).unwrap();
            let w = crate::model::WeightStore::init(cfg, 1);
            assert_eq!(w.param_count(), params(cfg, Sparsity::dense()), "{name}");
        }
    }

    #[test]
    fn pruning_reduces_counts_monotonically() {
        let cfg = ModelConfig::by_name("vit_h").unwrap();
        let mut prev_p = usize::MAX;
        let mut prev_f = usize::MAX;
        for s in 0..=7u8 {
            let sp = Sparsity::of(Scope::Both, s);
            let p = params(cfg, sp);
            let f = flops(cfg, sp);
            assert!(p <= prev_p && f <= prev_f, "s={s}");
            prev_p = p;
            prev_f = f;
        }
    }

    #[test]
    fn mlp_dominates_flops_reduction() {
        // Paper: MLP ≈ 30% of FLOPs, attention QK-dim pruning ≈ 12% — at 50%
        // sparsity the MLP scope must cut more FLOPs than the attn scope.
        let cfg = ModelConfig::by_name("vit_b").unwrap();
        let dense = flops(cfg, Sparsity::dense());
        let mlp50 = flops(cfg, Sparsity::of(Scope::Mlp, 5));
        let attn50 = flops(cfg, Sparsity::of(Scope::Attn, 5));
        let rd_mlp = reduction_pct(dense, mlp50);
        let rd_attn = reduction_pct(dense, attn50);
        assert!(rd_mlp > rd_attn, "mlp {rd_mlp:.1}% vs attn {rd_attn:.1}%");
        assert!(rd_mlp > 15.0 && rd_mlp < 45.0, "{rd_mlp}");
        assert!(rd_attn > 3.0 && rd_attn < 25.0, "{rd_attn}");
    }

    #[test]
    fn reduction_pct_basic() {
        assert_eq!(reduction_pct(100, 50), 50.0);
        assert_eq!(reduction_pct(0, 0), 0.0);
    }

    #[test]
    fn layered_matches_uniform_at_equal_dims() {
        use crate::model::LayerDims;
        for name in ["vit_t", "vit_b", "gpt_s"] {
            let cfg = ModelConfig::by_name(name).unwrap();
            for sp in [Sparsity::dense(), Sparsity::of(Scope::Both, 5)] {
                let (dqk, o) = cfg.pruned_dims(sp);
                let dims = LayerDims::uniform(cfg, dqk, o);
                assert_eq!(flops_layered(cfg, &dims), flops(cfg, sp), "{name} flops");
                assert_eq!(params_layered(cfg, &dims), params(cfg, sp), "{name} params");
            }
        }
    }

    #[test]
    fn unit_costs_are_exact_marginals() {
        use crate::model::LayerDims;
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let base = LayerDims::uniform(cfg, cfg.dh(), cfg.mlp);
        let f0 = flops_layered(cfg, &base);
        let mut one_mlp = base.clone();
        one_mlp.o[3] -= 1;
        assert_eq!(f0 - flops_layered(cfg, &one_mlp), mlp_unit_flops(cfg));
        let mut one_qk = base.clone();
        one_qk.dqk[1] -= 1;
        assert_eq!(f0 - flops_layered(cfg, &one_qk), qk_unit_flops(cfg));
    }
}
