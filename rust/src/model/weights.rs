//! Named weight store + deterministic init + binary checkpoints.
//!
//! The coordinator owns all weights as named f32 tensors. Checkpoints use a
//! tiny self-describing binary format (`CORPW1`): per tensor a name, shape,
//! and raw little-endian f32 payload — no external serialization crates.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::Pcg64;

/// Ordered map of parameter name -> tensor. BTreeMap keeps serialization
/// deterministic; lookups are by name via the config's param specs.
#[derive(Clone, Default)]
pub struct WeightStore {
    map: BTreeMap<String, Tensor>,
}

impl WeightStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn expect(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).with_context(|| format!("missing weight '{name}'"))
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.map.remove(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Deterministic "pretraining-style" init for a config (truncated normal
    /// 0.02 for projections, ones/zeros for norms and biases) — the starting
    /// point for the Rust training loop.
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let mut store = Self::new();
        for (name, shape) in cfg.param_spec() {
            let n: usize = shape.iter().product();
            let t = if name.ends_with(".g") {
                Tensor::from_vec(&shape, vec![1.0; n])
            } else if name.ends_with(".b")
                || name.ends_with(".bq")
                || name.ends_with(".bk")
                || name.ends_with(".bv")
                || name.ends_with(".bo")
                || name.ends_with(".b1")
                || name.ends_with(".b2")
            {
                Tensor::from_vec(&shape, vec![0.0; n])
            } else {
                let mut data = vec![0.0f32; n];
                for v in data.iter_mut() {
                    *v = rng.trunc_normal_f32(0.02);
                }
                // Positional embeddings and cls slightly larger, as in ViT.
                Tensor::from_vec(&shape, data)
            };
            store.insert(name, t);
        }
        store
    }

    /// Validate that every parameter in the config's dense spec is present
    /// with the right shape.
    pub fn validate_dense(&self, cfg: &ModelConfig) -> Result<()> {
        for (name, shape) in cfg.param_spec() {
            let t = self.expect(&name)?;
            if t.shape() != shape.as_slice() {
                bail!("weight '{name}': shape {:?} != spec {:?}", t.shape(), shape);
            }
        }
        Ok(())
    }

    // ---------------- checkpoint I/O ----------------

    const MAGIC: &'static [u8; 6] = b"CORPW1";

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        f.write_all(Self::MAGIC)?;
        f.write_all(&(self.map.len() as u32).to_le_bytes())?;
        for (name, t) in &self.map {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(t.ndim() as u32).to_le_bytes())?;
            for &d in t.shape() {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            // Raw LE f32 payload.
            for &v in t.data() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("bad checkpoint magic");
        }
        let count = read_u32(&mut f)? as usize;
        let mut store = Self::new();
        for _ in 0..count {
            let name_len = read_u32(&mut f)? as usize;
            if name_len > 4096 {
                bail!("implausible name length {name_len}");
            }
            let mut nb = vec![0u8; name_len];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb).context("invalid utf-8 weight name")?;
            let ndim = read_u32(&mut f)? as usize;
            if ndim > 8 {
                bail!("implausible ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            store.insert(name, Tensor::from_vec(&shape, data));
        }
        Ok(store)
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn init_covers_spec() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let w = WeightStore::init(cfg, 1);
        w.validate_dense(cfg).unwrap();
        // layernorm gains are ones, biases zeros.
        assert!(w.get("blocks.0.ln1.g").unwrap().data().iter().all(|&v| v == 1.0));
        assert!(w.get("blocks.0.attn.bq").unwrap().data().iter().all(|&v| v == 0.0));
        // projections are random (non-constant).
        let wq = w.get("blocks.0.attn.wq").unwrap();
        assert!(wq.data().iter().any(|&v| v != wq.data()[0]));
    }

    #[test]
    fn init_deterministic() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let a = WeightStore::init(cfg, 7);
        let b = WeightStore::init(cfg, 7);
        for (name, t) in a.iter() {
            assert_eq!(t.data(), b.get(name).unwrap().data(), "{name}");
        }
        let c = WeightStore::init(cfg, 8);
        assert_ne!(
            a.get("blocks.0.attn.wq").unwrap().data(),
            c.get("blocks.0.attn.wq").unwrap().data()
        );
    }

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let w = WeightStore::init(cfg, 3);
        let dir = std::env::temp_dir().join("corp_test_ckpt");
        let path = dir.join("t.corpw");
        w.save(&path).unwrap();
        let r = WeightStore::load(&path).unwrap();
        assert_eq!(w.len(), r.len());
        for (name, t) in w.iter() {
            let rt = r.get(name).unwrap();
            assert_eq!(t.shape(), rt.shape());
            assert_eq!(t.data(), rt.data());
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("corp_test_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.corpw");
        std::fs::write(&path, b"NOTFMT").unwrap();
        assert!(WeightStore::load(&path).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn param_count_sane() {
        // vit_t analytic: embed + blocks + head.
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let w = WeightStore::init(cfg, 1);
        let analytic = crate::flops::params(cfg, crate::model::Sparsity::dense());
        assert_eq!(w.param_count(), analytic);
    }
}
