//! Model configurations — must mirror `python/compile/model.py` exactly
//! (names, shapes, the canonical parameter order, and `keep_count`).

/// Transformer kind.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ModelKind {
    Vit,
    Gpt,
}

impl ModelKind {
    /// Serving-workload label (`corp serve`, `BENCH_serve.json` axes).
    pub fn workload_label(&self) -> &'static str {
        match self {
            ModelKind::Vit => "vision",
            ModelKind::Gpt => "text",
        }
    }
}

/// Pruning scope (which substructures are removed).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Scope {
    Mlp,
    Attn,
    Both,
}

impl Scope {
    pub fn label(&self) -> &'static str {
        match self {
            Scope::Mlp => "mlp",
            Scope::Attn => "attn",
            Scope::Both => "both",
        }
    }
}

/// Uniform sparsity in tenths (s10 = 5 ⇒ 50%), per scope.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Sparsity {
    pub mlp_s10: u8,
    pub attn_s10: u8,
}

impl Sparsity {
    pub fn dense() -> Self {
        Self { mlp_s10: 0, attn_s10: 0 }
    }

    pub fn of(scope: Scope, s10: u8) -> Self {
        match scope {
            Scope::Mlp => Self { mlp_s10: s10, attn_s10: 0 },
            Scope::Attn => Self { mlp_s10: 0, attn_s10: s10 },
            Scope::Both => Self { mlp_s10: s10, attn_s10: s10 },
        }
    }

    pub fn is_dense(&self) -> bool {
        self.mlp_s10 == 0 && self.attn_s10 == 0
    }
}

/// Kept size of a dimension at sparsity s10/10. Integer arithmetic identical
/// to the Python side so artifact shapes agree bit-exactly.
pub fn keep_count(dim: usize, s10: u8) -> usize {
    assert!(s10 <= 9);
    ((dim * (10 - s10 as usize) + 5) / 10).max(1)
}

/// Per-layer retained dims: `dqk[l]` per-head q/k width and `o[l]` MLP
/// hidden width of layer `l`. The global-FLOPs-budget allocator produces
/// these; the uniform `Sparsity` path is the special case where every entry
/// is equal.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct LayerDims {
    pub dqk: Vec<usize>,
    pub o: Vec<usize>,
}

impl LayerDims {
    /// Uniform dims (one `(dqk, o)` repeated across layers).
    pub fn uniform(cfg: &ModelConfig, dqk: usize, o: usize) -> Self {
        Self { dqk: vec![dqk; cfg.layers], o: vec![o; cfg.layers] }
    }

    /// `Some((dqk, o))` when every layer shares one shape — such stores can
    /// use the uniform `fwd_*`/`dec_*` artifacts and the q8/decode paths.
    pub fn as_uniform(&self) -> Option<(usize, usize)> {
        let (&q0, &o0) = (self.dqk.first()?, self.o.first()?);
        (self.dqk.iter().all(|&q| q == q0) && self.o.iter().all(|&o| o == o0))
            .then_some((q0, o0))
    }

    /// Dash-joined dim list for layered artifact names (`16-16-12`).
    fn dims_token(dims: &[usize]) -> String {
        dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("-")
    }
}

/// Static model configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub kind: ModelKind,
    pub d: usize,
    pub heads: usize,
    pub layers: usize,
    pub mlp: usize,
    /// vit: patches + 1 (CLS); gpt: sequence length.
    pub n_ctx: usize,
    pub patches: usize,
    pub patch_dim: usize,
    pub classes: usize,
    pub vocab: usize,
}

impl ModelConfig {
    /// Per-head q/k/v dimension of the dense model.
    pub fn dh(&self) -> usize {
        debug_assert_eq!(self.d % self.heads, 0);
        self.d / self.heads
    }

    /// Batch size the eval/calibration/throughput artifacts were lowered at.
    pub fn eval_batch(&self) -> usize {
        match self.kind {
            ModelKind::Vit => 16,
            ModelKind::Gpt => 8,
        }
    }

    pub fn by_name(name: &str) -> Option<&'static ModelConfig> {
        FAMILY.iter().find(|c| c.name == name)
    }

    /// Kept per-head q/k dim and MLP hidden dim at a sparsity setting.
    pub fn pruned_dims(&self, sp: Sparsity) -> (usize, usize) {
        let dqk = if sp.attn_s10 == 0 { self.dh() } else { keep_count(self.dh(), sp.attn_s10) };
        let o = if sp.mlp_s10 == 0 { self.mlp } else { keep_count(self.mlp, sp.mlp_s10) };
        (dqk, o)
    }

    /// Canonical full-model parameter order (names + shapes), mirroring
    /// `model.param_spec`.
    pub fn param_spec(&self) -> Vec<(String, Vec<usize>)> {
        self.param_spec_at(self.dh(), self.mlp)
    }

    /// Full-model parameter order at explicit pruned dims `(dqk, o)` — the
    /// input convention of the fused `fwd_*` artifacts. Dense shapes are
    /// `param_spec_at(dh(), mlp)`.
    pub fn param_spec_at(&self, dqk: usize, o: usize) -> Vec<(String, Vec<usize>)> {
        let mut spec = self.embed_param_spec();
        for layer in 0..self.layers {
            for (n, s) in self.block_param_spec(dqk, o) {
                spec.push((format!("blocks.{layer}.{n}"), s));
            }
        }
        spec.extend(self.head_param_spec());
        spec
    }

    pub fn embed_param_spec(&self) -> Vec<(String, Vec<usize>)> {
        match self.kind {
            ModelKind::Vit => vec![
                ("embed.w".into(), vec![self.patch_dim, self.d]),
                ("embed.b".into(), vec![self.d]),
                ("embed.cls".into(), vec![self.d]),
                ("embed.pos".into(), vec![self.n_ctx, self.d]),
            ],
            ModelKind::Gpt => vec![
                ("embed.w".into(), vec![self.vocab, self.d]),
                ("embed.pos".into(), vec![self.n_ctx, self.d]),
            ],
        }
    }

    pub fn block_param_spec(&self, dqk: usize, o: usize) -> Vec<(String, Vec<usize>)> {
        let (d, h, dh) = (self.d, self.heads, self.dh());
        vec![
            ("ln1.g".into(), vec![d]),
            ("ln1.b".into(), vec![d]),
            ("attn.wq".into(), vec![d, h * dqk]),
            ("attn.bq".into(), vec![h * dqk]),
            ("attn.wk".into(), vec![d, h * dqk]),
            ("attn.bk".into(), vec![h * dqk]),
            ("attn.wv".into(), vec![d, h * dh]),
            ("attn.bv".into(), vec![h * dh]),
            ("attn.wo".into(), vec![h * dh, d]),
            ("attn.bo".into(), vec![d]),
            ("ln2.g".into(), vec![d]),
            ("ln2.b".into(), vec![d]),
            ("mlp.w1".into(), vec![d, o]),
            ("mlp.b1".into(), vec![o]),
            ("mlp.w2".into(), vec![o, d]),
            ("mlp.b2".into(), vec![d]),
        ]
    }

    /// Full-model parameter order at per-layer dims — the layered analogue
    /// of [`ModelConfig::param_spec_at`], consumed by the layered `fwd_*`
    /// artifacts the allocator's non-uniform stores dispatch through.
    pub fn param_spec_layered(&self, dims: &LayerDims) -> Vec<(String, Vec<usize>)> {
        assert_eq!(dims.dqk.len(), self.layers);
        assert_eq!(dims.o.len(), self.layers);
        let mut spec = self.embed_param_spec();
        for layer in 0..self.layers {
            for (n, s) in self.block_param_spec(dims.dqk[layer], dims.o[layer]) {
                spec.push((format!("blocks.{layer}.{n}"), s));
            }
        }
        spec.extend(self.head_param_spec());
        spec
    }

    pub fn head_param_spec(&self) -> Vec<(String, Vec<usize>)> {
        let out = match self.kind {
            ModelKind::Vit => self.classes,
            ModelKind::Gpt => self.vocab,
        };
        vec![
            ("head.ln.g".into(), vec![self.d]),
            ("head.ln.b".into(), vec![self.d]),
            ("head.w".into(), vec![self.d, out]),
            ("head.b".into(), vec![out]),
        ]
    }

    /// Artifact names for this config at given pruned dims / batch.
    pub fn block_artifact(&self, dqk: usize, o: usize, batch: usize) -> String {
        format!("block_{}_q{dqk}_o{o}_b{batch}", self.name)
    }

    pub fn embed_artifact(&self, batch: usize) -> String {
        format!("embed_{}_b{batch}", self.name)
    }

    /// Fused full-forward artifact (embed + all blocks + head in one
    /// dispatch) at pruned dims `(dqk, o)` — the serving fast path.
    pub fn fwd_artifact(&self, dqk: usize, o: usize, batch: usize) -> String {
        format!("fwd_{}_q{dqk}_o{o}_b{batch}", self.name)
    }

    /// Layered fused-forward artifact for per-layer retained dims: the
    /// dims are dash-joined per layer (`fwd_vit_t_qv16-16-12_ov192-200-88_b8`).
    /// Uniform dims still use [`ModelConfig::fwd_artifact`] — the layered
    /// name exists only for allocator-produced non-uniform stores and is
    /// served by the native interpreter only.
    pub fn fwd_artifact_layered(&self, dims: &LayerDims, batch: usize) -> String {
        format!(
            "fwd_{}_qv{}_ov{}_b{batch}",
            self.name,
            LayerDims::dims_token(&dims.dqk),
            LayerDims::dims_token(&dims.o)
        )
    }

    /// Incremental (KV-cached) decode artifact at pruned dims `(dqk, o)` —
    /// embeds only the *new* positions of each sequence and attends over the
    /// per-layer K/V cache (the autoregressive serving fast path; gpt only).
    pub fn dec_artifact(&self, dqk: usize, o: usize, batch: usize) -> String {
        format!("dec_{}_q{dqk}_o{o}_b{batch}", self.name)
    }

    pub fn head_artifact(&self, batch: usize) -> String {
        format!("head_{}_b{batch}", self.name)
    }

    pub fn blockcap_artifact(&self) -> String {
        format!("blockcap_{}_b{}", self.name, self.eval_batch())
    }

    pub fn train_artifact(&self) -> String {
        format!("train_{}", self.name)
    }

    pub fn evloss_artifact(&self) -> String {
        format!("evloss_{}", self.name)
    }

    pub fn lnf_artifact(&self) -> String {
        format!("lnf_{}_b{}", self.name, self.eval_batch())
    }
}

const fn vit(name: &'static str, d: usize, heads: usize, layers: usize, mlp: usize) -> ModelConfig {
    ModelConfig {
        name,
        kind: ModelKind::Vit,
        d,
        heads,
        layers,
        mlp,
        n_ctx: 17,
        patches: 16,
        patch_dim: 48,
        classes: 16,
        vocab: 0,
    }
}

/// The scaled DeiT family + the OPT-substitute GPT (see DESIGN.md).
pub static FAMILY: &[ModelConfig] = &[
    vit("vit_t", 96, 3, 6, 384),
    vit("vit_s", 128, 4, 8, 512),
    vit("vit_b", 192, 6, 10, 768),
    vit("vit_l", 256, 8, 12, 1024),
    vit("vit_h", 320, 10, 14, 1280),
    ModelConfig {
        name: "gpt_s",
        kind: ModelKind::Gpt,
        d: 128,
        heads: 4,
        layers: 6,
        mlp: 512,
        n_ctx: 64,
        patches: 0,
        patch_dim: 0,
        classes: 0,
        vocab: 96,
    },
];

/// The five ViT sizes in paper order (Tiny..Huge analogues).
pub fn vit_family() -> Vec<&'static ModelConfig> {
    FAMILY.iter().filter(|c| c.kind == ModelKind::Vit).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_count_matches_python() {
        // Spot values must agree with model.keep_count (integer identical).
        assert_eq!(keep_count(32, 0), 32);
        assert_eq!(keep_count(32, 5), 16);
        assert_eq!(keep_count(32, 3), 22);
        assert_eq!(keep_count(32, 7), 10);
        assert_eq!(keep_count(384, 5), 192);
        assert_eq!(keep_count(768, 3), 538);
        assert_eq!(keep_count(1, 7), 1); // floor at 1
    }

    #[test]
    fn keep_count_monotone() {
        for dim in [32usize, 384, 1280] {
            let mut prev = dim + 1;
            for s in 0..=7u8 {
                let k = keep_count(dim, s);
                assert!(k <= prev && k >= 1);
                prev = k;
            }
        }
    }

    #[test]
    fn family_heads_divide() {
        for c in FAMILY {
            assert_eq!(c.d % c.heads, 0);
            assert_eq!(c.dh(), 32);
        }
    }

    #[test]
    fn param_spec_counts() {
        let c = ModelConfig::by_name("vit_t").unwrap();
        let spec = c.param_spec();
        assert_eq!(spec.len(), 4 + 16 * c.layers + 4);
        // Unique names.
        let mut names: Vec<&str> = spec.iter().map(|(n, _)| n.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), spec.len());
    }

    #[test]
    fn pruned_dims_per_scope() {
        let c = ModelConfig::by_name("vit_b").unwrap();
        let (q, o) = c.pruned_dims(Sparsity::of(Scope::Mlp, 5));
        assert_eq!((q, o), (32, 384));
        let (q, o) = c.pruned_dims(Sparsity::of(Scope::Attn, 5));
        assert_eq!((q, o), (16, 768));
        let (q, o) = c.pruned_dims(Sparsity::of(Scope::Both, 5));
        assert_eq!((q, o), (16, 384));
        let (q, o) = c.pruned_dims(Sparsity::dense());
        assert_eq!((q, o), (32, 768));
    }

    #[test]
    fn artifact_names() {
        let c = ModelConfig::by_name("vit_t").unwrap();
        assert_eq!(c.block_artifact(32, 384, 16), "block_vit_t_q32_o384_b16");
        assert_eq!(c.embed_artifact(1), "embed_vit_t_b1");
        assert_eq!(c.blockcap_artifact(), "blockcap_vit_t_b16");
        assert_eq!(c.fwd_artifact(16, 192, 8), "fwd_vit_t_q16_o192_b8");
        let g = ModelConfig::by_name("gpt_s").unwrap();
        assert_eq!(g.dec_artifact(16, 256, 4), "dec_gpt_s_q16_o256_b4");
    }

    #[test]
    fn pruned_param_spec_shapes() {
        let c = ModelConfig::by_name("vit_t").unwrap();
        let spec = c.param_spec_at(16, 192);
        let wq = spec.iter().find(|(n, _)| n == "blocks.0.attn.wq").unwrap();
        assert_eq!(wq.1, vec![c.d, c.heads * 16]);
        let w1 = spec.iter().find(|(n, _)| n == "blocks.0.mlp.w1").unwrap();
        assert_eq!(w1.1, vec![c.d, 192]);
        // The dense spec is the (dh, mlp) instance of the pruned spec.
        assert_eq!(c.param_spec(), c.param_spec_at(c.dh(), c.mlp));
    }

    #[test]
    fn layer_dims_uniform_roundtrip() {
        let c = ModelConfig::by_name("vit_t").unwrap();
        let u = LayerDims::uniform(c, 16, 192);
        assert_eq!(u.as_uniform(), Some((16, 192)));
        let mut nu = u.clone();
        nu.o[2] = 200;
        assert_eq!(nu.as_uniform(), None);
        // Layered spec at uniform dims == the uniform spec.
        assert_eq!(c.param_spec_layered(&u), c.param_spec_at(16, 192));
        // Non-uniform spec reflects each layer's own dims.
        let spec = c.param_spec_layered(&nu);
        let w1 = spec.iter().find(|(n, _)| n == "blocks.2.mlp.w1").unwrap();
        assert_eq!(w1.1, vec![c.d, 200]);
        let w1b = spec.iter().find(|(n, _)| n == "blocks.0.mlp.w1").unwrap();
        assert_eq!(w1b.1, vec![c.d, 192]);
    }

    #[test]
    fn layered_artifact_name() {
        let c = ModelConfig::by_name("vit_t").unwrap();
        let dims = LayerDims {
            dqk: vec![16, 16, 12, 16, 16, 16],
            o: vec![192, 200, 88, 192, 192, 192],
        };
        assert_eq!(
            c.fwd_artifact_layered(&dims, 8),
            "fwd_vit_t_qv16-16-12-16-16-16_ov192-200-88-192-192-192_b8"
        );
    }

    #[test]
    fn workload_labels() {
        assert_eq!(ModelKind::Vit.workload_label(), "vision");
        assert_eq!(ModelKind::Gpt.workload_label(), "text");
    }

    #[test]
    fn gpt_config() {
        let g = ModelConfig::by_name("gpt_s").unwrap();
        assert_eq!(g.kind, ModelKind::Gpt);
        assert_eq!(g.eval_batch(), 8);
        let spec = g.param_spec();
        assert_eq!(spec.len(), 2 + 16 * g.layers + 4);
    }
}
