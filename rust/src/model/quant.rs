//! Int8 weight-quantized store: the post-pruning `quantize` weight
//! transform.
//!
//! A [`QuantStore`] holds a model's six per-block GEMM projections
//! (`attn.wq/wk/wv/wo`, `mlp.w1/w2`) as per-output-channel int8
//! [`QuantMat`]s and everything else (norms, biases, embeddings, head)
//! as f32 in an ordinary [`WeightStore`]. It is produced *after* pruning
//! and compensation — quantization composes with CORP's structural edits,
//! and the dequant-correction pass in `compensate::quant` then folds the
//! quantization residual of `mlp.w2` into the stored scales/bias using the
//! same calibration Gram accumulators the pruning compensator uses.
//!
//! The base store keeps the param-spec *shapes* observable through
//! [`QuantStore::shape_of`] so the executor can derive the served
//! `(dqk, o)` dims exactly as it does from a dense store.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::config::ModelConfig;
use super::weights::WeightStore;
use crate::linalg::qgemm::{quantize, QuantMat};

/// Is `name` one of the per-block GEMM projections the int8 path
/// quantizes? (`attn.wq/wk/wv/wo` and `mlp.w1/w2`, with or without the
/// `blocks.{l}.` prefix — `block_param_spec` names are unprefixed;
/// embeddings, head, norms, and all biases stay f32.)
pub fn is_q8_param(name: &str) -> bool {
    name.contains("attn.w") || name.contains("mlp.w")
}

/// A weight store whose block GEMM projections are int8-quantized.
#[derive(Clone, Default)]
pub struct QuantStore {
    /// All non-quantized parameters (f32), under their usual names.
    base: WeightStore,
    /// The quantized projections, keyed by the same param names.
    q: BTreeMap<String, QuantMat>,
}

impl QuantStore {
    /// Quantize a (dense or pruned/compensated) store. The input may carry
    /// pruned shapes; shapes are read off the stored tensors, matching the
    /// fused-artifact convention.
    pub fn from_store(cfg: &ModelConfig, w: &WeightStore) -> Result<Self> {
        let mut base = WeightStore::new();
        let mut q = BTreeMap::new();
        for (name, t) in w.iter() {
            if is_q8_param(name) {
                let s = t.shape();
                if s.len() != 2 {
                    bail!("quantize: '{name}' is not a matrix (shape {s:?})");
                }
                q.insert(name.to_string(), quantize(t.data(), s[0], s[1]));
            } else {
                base.insert(name, t.clone());
            }
        }
        if q.is_empty() {
            bail!("quantize: no block GEMM projections found ({} params)", w.len());
        }
        // Sanity: every layer contributed its six projections.
        let expected = 6 * cfg.layers;
        if q.len() != expected {
            bail!("quantize: {} quantized projections, expected {expected}", q.len());
        }
        Ok(Self { base, q })
    }

    /// The f32 remainder (norms, biases, embeddings, head).
    pub fn base(&self) -> &WeightStore {
        &self.base
    }

    pub fn get_q(&self, name: &str) -> Option<&QuantMat> {
        self.q.get(name)
    }

    pub fn expect_q(&self, name: &str) -> Result<&QuantMat> {
        self.q.get(name).with_context(|| format!("missing quantized weight '{name}'"))
    }

    /// Mutable access for the dequant-correction fold (scales only; codes
    /// are never rewritten).
    pub fn get_q_mut(&mut self, name: &str) -> Option<&mut QuantMat> {
        self.q.get_mut(name)
    }

    /// Mutable access to the f32 remainder (bias folds).
    pub fn base_mut(&mut self) -> &mut WeightStore {
        &mut self.base
    }

    /// Shape of any parameter, quantized or not — `[din, dout]` for
    /// quantized projections, the tensor shape otherwise.
    pub fn shape_of(&self, name: &str) -> Option<Vec<usize>> {
        if let Some(qm) = self.q.get(name) {
            return Some(vec![qm.din, qm.dout]);
        }
        self.base.get(name).map(|t| t.shape().to_vec())
    }

    pub fn quantized_names(&self) -> impl Iterator<Item = &str> {
        self.q.keys().map(|s| s.as_str())
    }

    /// Payload bytes of the store (int8 codes + scales + f32 remainder) —
    /// the memory win `bench linalg` reports against the f32 store.
    pub fn bytes(&self) -> usize {
        self.q.values().map(|qm| qm.bytes()).sum::<usize>() + self.base.param_count() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qgemm::dequant;

    #[test]
    fn is_q8_param_selects_projections_only() {
        for n in [
            "blocks.0.attn.wq",
            "blocks.3.attn.wk",
            "blocks.1.attn.wv",
            "blocks.5.attn.wo",
            "blocks.2.mlp.w1",
            "blocks.0.mlp.w2",
            // block_param_spec's unprefixed forms
            "attn.wq",
            "mlp.w2",
        ] {
            assert!(is_q8_param(n), "{n}");
        }
        for n in [
            "blocks.0.attn.bq",
            "blocks.0.mlp.b1",
            "blocks.0.ln1.g",
            "embed.w",
            "embed.pos",
            "head.w",
            "head.ln.g",
        ] {
            assert!(!is_q8_param(n), "{n}");
        }
    }

    #[test]
    fn from_store_partitions_params() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let w = WeightStore::init(cfg, 1);
        let qs = QuantStore::from_store(cfg, &w).unwrap();
        assert_eq!(qs.quantized_names().count(), 6 * cfg.layers);
        // Base lacks the projections, keeps everything else.
        assert!(qs.base().get("blocks.0.attn.wq").is_none());
        assert!(qs.base().get("blocks.0.attn.bq").is_some());
        assert!(qs.base().get("embed.w").is_some());
        assert!(qs.base().get("head.w").is_some());
        // Shapes survive.
        assert_eq!(qs.shape_of("blocks.0.attn.wq").unwrap(), vec![cfg.d, cfg.d]);
        assert_eq!(qs.shape_of("blocks.0.mlp.w1").unwrap(), vec![cfg.d, cfg.mlp]);
        assert_eq!(
            qs.shape_of("embed.pos").unwrap(),
            w.get("embed.pos").unwrap().shape().to_vec()
        );
        // Int8 payload is meaningfully smaller than f32.
        assert!(qs.bytes() < w.param_count() * 4);
    }

    #[test]
    fn quantized_payload_reconstructs() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let w = WeightStore::init(cfg, 2);
        let qs = QuantStore::from_store(cfg, &w).unwrap();
        let qm = qs.expect_q("blocks.0.mlp.w2").unwrap();
        let dq = dequant(qm);
        let orig = w.get("blocks.0.mlp.w2").unwrap().data();
        for (a, b) in dq.iter().zip(orig) {
            // Round-trip within half a step of the channel scale; scales
            // are bounded by the column max.
            assert!((a - b).abs() <= 0.5 * qm.scales.iter().fold(0.0f32, |m, &s| m.max(s)) + 1e-6);
        }
    }

    #[test]
    fn from_store_rejects_empty() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let w = WeightStore::new();
        assert!(QuantStore::from_store(cfg, &w).is_err());
    }
}
