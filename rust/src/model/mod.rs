//! Model definitions on the Rust side: configs (mirroring
//! `python/compile/model.py`), the named weight store, deterministic init,
//! checkpoint serialization, and pruned-shape derivation.

pub mod config;
pub mod quant;
pub mod weights;

pub use config::{keep_count, LayerDims, ModelConfig, ModelKind, Scope, Sparsity};
pub use quant::{is_q8_param, QuantStore};
pub use weights::WeightStore;
