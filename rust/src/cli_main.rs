//! CLI dispatch for the `corp` binary.
//!
//! Subcommands:
//!   train    — train (or load) a dense checkpoint, print the loss curve tail
//!   prune    — run the CORP pipeline at a sparsity/method and report accuracy
//!   eval     — evaluate a checkpoint (dense or pruned) on the eval split
//!   serve    — run the dynamic batcher on a (pruned) model
//!   generate — autoregressive greedy generation (KV-cache vs prefill)
//!   stats    — print the Table-9 redundancy statistics for a model
//!   list     — list models and artifact status

use anyhow::{bail, Context, Result};

use crate::coordinator::Coordinator;
use crate::exec::{DecodeMode, KvPoolOpts};
use crate::model::{ModelConfig, ModelKind, Scope, Sparsity};
use crate::prune::{Method, PruneOpts};
use crate::rank::{Criterion, MlpCriterion};
use crate::util::cli::Command;

fn parse_scope(s: &str) -> Result<Scope> {
    Ok(match s {
        "mlp" => Scope::Mlp,
        "attn" => Scope::Attn,
        "both" => Scope::Both,
        _ => bail!("scope must be mlp|attn|both, got '{s}'"),
    })
}

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "corp" => Method::Corp,
        "naive" => Method::Naive,
        "grail" => Method::Grail,
        "vbp" => Method::Vbp,
        _ => bail!("method must be corp|naive|grail|vbp, got '{s}'"),
    })
}

fn parse_criterion(s: &str) -> Result<Criterion> {
    Ok(match s {
        "act" => Criterion::Mlp(MlpCriterion::ActEnergy),
        "mag" => Criterion::Mlp(MlpCriterion::Magnitude),
        "combined" => Criterion::Mlp(MlpCriterion::Combined),
        "active" => Criterion::Mlp(MlpCriterion::ActiveProb),
        "variance" => Criterion::Variance,
        "obs" => Criterion::Obs,
        "energy" => Criterion::Energy,
        _ => bail!("criterion must be combined|act|mag|active|variance|obs|energy, got '{s}'"),
    })
}

fn cfg_of(name: &str) -> Result<&'static ModelConfig> {
    ModelConfig::by_name(name).with_context(|| format!("unknown model '{name}'"))
}

pub fn run_cli(argv: &[String]) -> Result<()> {
    let Some(sub) = argv.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match sub {
        "train" => cmd_train(rest),
        "prune" => cmd_prune(rest),
        "serve" => cmd_serve(rest),
        "generate" => cmd_generate(rest),
        "stats" => cmd_stats(rest),
        "bench" => cmd_bench(rest),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `corp help`)"),
    }
}

fn print_usage() {
    println!(
        "corp — CORP one-shot structured pruning (paper reproduction)\n\n\
         subcommands:\n  \
         train  --model vit_b [--steps N]        train/load the dense checkpoint\n  \
         prune  --model vit_b --scope both --sparsity 0.5 [--method corp] [--criterion combined]\n  \
         prune  --model vit_b --flops-budget 60 [--criterion energy]   global FLOPs-targeted allocation\n  \
         serve  --model vit_b --sparsity 0.5 [--workers 2] [--rate 200] [--dispatch auto]\n  \
         serve  --model gpt_s [--workload text|gen] [--prefill-chunk N] [--shared-prefix N]\n  \
         serve  ... [--controller] [--slo-p99-ms 50] [--degrade] [--spike 3]   SLO feedback loop\n  \
         serve  ... [--request-timeout-ms 250] [--retries 2] [--chaos kill=0@1,fail=3]   fault tolerance\n  \
         generate --model gpt_s --tokens 8 [--decode kv|prefill] [--prefill-chunk N] [--verify]\n  \
         stats  --model vit_b                    Table-9 redundancy statistics\n  \
         bench  linalg|serve|prune [--json] [--out PATH]  perf harnesses (BENCH_*.json)\n  \
         list                                    models + artifact status"
    );
}

fn cmd_bench(argv: &[String]) -> Result<()> {
    let cmd = Command::new("bench", "performance harness")
        .flag("json", "emit machine-readable results")
        .opt("out", "output path for --json (default BENCH_<target>.json)", "");
    let args = cmd.parse(argv)?;
    let target = args.positional().first().map(|s| s.as_str()).unwrap_or("linalg");
    let out = args.str("out");
    let out = if out.is_empty() { format!("BENCH_{target}.json") } else { out };
    let json = args.has_flag("json").then_some(out.as_str());
    match target {
        "linalg" => crate::bench_tables::linalg::bench_linalg(json),
        "serve" => crate::bench_tables::serve::bench_serve(json),
        "prune" => crate::bench_tables::prune::bench_prune(json),
        other => bail!("unknown bench target '{other}' (available: linalg, serve, prune)"),
    }
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train or load a dense checkpoint")
        .opt("model", "model name", "vit_b")
        .opt("steps", "training steps (0 = mode default)", "0");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    let mut coord = Coordinator::new()?;
    let steps = args.usize("steps")?;
    let w = if steps > 0 {
        let opts = crate::train::TrainOpts { steps, ..coord.train_opts(cfg) };
        crate::train::ensure_checkpoint(&coord.rt, cfg, &opts)?
    } else {
        coord.dense(cfg)?.clone()
    };
    match cfg.kind {
        crate::model::ModelKind::Vit => {
            let acc = coord.top1(cfg, &w, 99)?;
            println!("{}: {} params, top-1 {acc:.2}%", cfg.name, w.param_count());
        }
        crate::model::ModelKind::Gpt => {
            let exec = coord.executor(cfg);
            let gen = crate::data::TextGen::new(crate::data::DATA_SEED);
            let ppl = crate::eval::ppl_stitched(&exec, &w, &gen, 8)?;
            println!("{}: {} params, eval ppl {ppl:.3}", cfg.name, w.param_count());
        }
    }
    Ok(())
}

fn cmd_prune(argv: &[String]) -> Result<()> {
    let cmd = Command::new("prune", "run the one-shot pruning pipeline")
        .opt("model", "model name", "vit_b")
        .opt("scope", "mlp|attn|both", "both")
        .opt("sparsity", "0.0-0.7", "0.5")
        .opt("method", "corp|naive|grail|vbp", "corp")
        .opt("criterion", "combined|act|mag|active|variance|obs|energy", "combined")
        .opt("flops-budget", "global FLOPs budget, % of dense (0 = uniform --sparsity)", "0")
        .opt("lambda", "ridge strength", "0.01")
        .opt("calib", "calibration batches", "16");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    let scope = parse_scope(&args.str("scope"))?;
    let s10 = (args.f64("sparsity")? * 10.0).round() as u8;
    if s10 > 7 {
        bail!("sparsity must be <= 0.7 (artifact grid)");
    }
    let budget = args.f64("flops-budget")?;
    if budget > 0.0 && scope != Scope::Both {
        bail!("--flops-budget allocates both scopes jointly; drop --scope or use 'both'");
    }
    let mut coord = Coordinator::new()?;
    let opts = PruneOpts {
        method: parse_method(&args.str("method"))?,
        criterion: parse_criterion(&args.str("criterion"))?,
        lambda: args.f64("lambda")?,
        calib_batches: args.usize("calib")?,
        ..PruneOpts::default()
    };
    let dense_acc = {
        let w = coord.dense(cfg)?.clone();
        coord.top1(cfg, &w, 99)?
    };
    if budget > 0.0 {
        return prune_with_budget(&mut coord, cfg, opts, budget, dense_acc);
    }
    let sp = Sparsity::of(scope, s10);
    let (acc, p, f, sections) = coord.accuracy_at(cfg, sp, opts.method, &opts)?;
    let pd = crate::flops::params(cfg, Sparsity::dense());
    let fd = crate::flops::flops(cfg, Sparsity::dense());
    println!(
        "{} {} s={:.1} [{}]: top-1 {acc:.2}% (dense {dense_acc:.2}%)  params {:.2}M (-{:.1}%)  flops {:.1}M (-{:.1}%)",
        cfg.name,
        scope.label(),
        s10 as f64 / 10.0,
        opts.method.label(),
        p as f64 / 1e6,
        crate::flops::reduction_pct(pd, p),
        f as f64 / 1e6,
        crate::flops::reduction_pct(fd, f),
    );
    println!(
        "pipeline: calibration {:.2}s  ranking {:.3}s  compensation {:.2}s",
        sections.get("calibration"),
        sections.get("ranking"),
        sections.get("compensation")
    );
    Ok(())
}

/// `corp prune --flops-budget <pct>`: global FLOPs-targeted allocation.
/// Calibrates once, lets the greedy allocator pick per-layer keep counts
/// under the budget, prunes with those counts, and reports the achieved
/// FLOPs measured on the *actual* pruned per-layer shapes.
fn prune_with_budget(
    coord: &mut Coordinator,
    cfg: &'static ModelConfig,
    opts: PruneOpts,
    budget: f64,
    dense_acc: f64,
) -> Result<()> {
    let dense = coord.dense(cfg)?.clone();
    coord.calib(cfg, &opts)?;
    let key = format!("{}@{}", cfg.name, opts.calib_batches);
    let alloc = {
        let stats = coord.calib_stats(&key);
        crate::prune::allocate_flops(cfg, &dense, stats, opts.criterion, opts.lambda, budget)?
    };
    let opts = PruneOpts { alloc: Some(alloc.clone()), ..opts };
    let result = coord.prune_job(cfg, &opts)?;
    let acc = coord.top1(cfg, &result.weights, opts.seed)?;
    // Measure on the shapes the pruner actually produced, not the plan.
    let exec = coord.executor(cfg);
    let dims = exec.stored_layer_dims(&result.weights)?;
    let p = crate::flops::params_layered(cfg, &dims);
    let f = crate::flops::flops_layered(cfg, &dims);
    let pd = crate::flops::params(cfg, Sparsity::dense());
    let fd = crate::flops::flops(cfg, Sparsity::dense());
    println!(
        "{} flops-budget {budget:.1}% [{} / {}]: top-1 {acc:.2}% (dense {dense_acc:.2}%)  \
         params {:.2}M (-{:.1}%)  flops {:.1}M (-{:.1}%, achieved {:.1}% of dense)",
        cfg.name,
        opts.method.label(),
        opts.criterion.label(),
        p as f64 / 1e6,
        crate::flops::reduction_pct(pd, p),
        f as f64 / 1e6,
        crate::flops::reduction_pct(fd, f),
        100.0 * f as f64 / fd as f64,
    );
    println!("allocation: mlp keep {:?}  qk keep {:?}", alloc.mlp_keep, alloc.qk_keep);
    println!(
        "pipeline: calibration {:.2}s  ranking {:.3}s  compensation {:.2}s",
        result.sections.get("calibration"),
        result.sections.get("ranking"),
        result.sections.get("compensation")
    );
    Ok(())
}

/// Serve one workload, routing through [`crate::serve::run_fleet`] when a
/// degraded-variant fallback store is present (the controller needs a
/// second plan rung to switch to), the int8 engine entry point
/// ([`crate::serve::run_engine_q8`]) when serving a quantized store
/// directly, and the plain single-store [`crate::serve::run_engine`]
/// otherwise. With both a fallback and a quantized store, the int8 rung
/// is appended *after* the pruned+compensated one — the controller's
/// cheapest last resort (dense → pruned+compensated →
/// pruned+compensated+int8).
fn serve_one<W: crate::serve::Workload>(
    exec: &crate::exec::Executor<'_>,
    weights: &crate::model::WeightStore,
    fallback: Option<&crate::model::WeightStore>,
    quant: Option<&crate::model::QuantStore>,
    workload: &W,
    eopts: &crate::serve::EngineOpts,
) -> Result<crate::serve::EngineStats> {
    match (fallback, quant) {
        (Some(fb), q) => {
            let mut m = crate::serve::FleetMember::new(exec, weights, workload, eopts.requests)
                .with_fallback(fb);
            if let Some(qs) = q {
                m = m.with_quant_fallback(qs);
            }
            let mut v = crate::serve::run_fleet(vec![m.erased()], eopts)?;
            Ok(v.remove(0))
        }
        (None, Some(qs)) => crate::serve::run_engine_q8(exec, qs, workload, eopts),
        (None, None) => crate::serve::run_engine(exec, weights, workload, eopts),
    }
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "concurrent batched serving engine")
        .opt("model", "model name (vit_* → vision workload, gpt_* → text)", "vit_b")
        .opt("workload", "scenario: auto|vision|text|gen (auto = model kind)", "auto")
        .opt("sparsity", "joint sparsity 0.0-0.7", "0.5")
        .opt("workers", "executor threads", "2")
        .opt("rate", "arrival rate req/s (0 = saturated)", "200")
        .opt("requests", "total requests", "256")
        .opt("max-batch", "max requests per batch", "16")
        .opt("max-wait-ms", "batching deadline, ms", "10")
        .opt("queue-cap", "queue bound (excess is shed)", "1024")
        .opt("exec-floor", "minimum per-batch execution time, seconds (load shaping)", "0")
        .opt("seed", "arrival-process seed", "7")
        .opt("dispatch", "batch dispatch shape: padded|exact|auto", "auto")
        .opt("max-new", "gen workload: max tokens generated per request", "8")
        .opt("decode", "gen workload decode path: auto|kv|prefill", "auto")
        .opt("kv-block", "KV pool: positions per block (0 = default)", "0")
        .opt("kv-blocks", "KV pool: capacity in blocks (0 = unbounded)", "0")
        .opt("prefill-chunk", "gen workload: max prompt tokens fed per step (0 = one-shot)", "0")
        .opt("shared-prefix", "gen workload: common prompt-opening length to stamp (0 = off)", "0")
        .opt("spike", "arrival-rate multiplier over the middle third of the schedule", "1")
        .opt("slo-p99-ms", "p99 latency budget, ms (0 = none)", "0")
        .opt("request-timeout-ms", "per-request deadline per attempt, ms (0 = none)", "0")
        .opt("retries", "retry budget for timed-out/faulted requests", "0")
        .opt("retry-backoff-ms", "base re-enqueue backoff, ms (doubles per retry; 0 = immediate)", "0")
        .opt("chaos", "deterministic fault plan: kill=W@B,fail=ID[@STEP],delay=ID:MS (empty = off)", "")
        .flag("controller", "enable the SLO feedback controller (adaptive wait + dispatch threshold)")
        .flag("degrade", "let the controller fall back to the pruned+compensated variant under load")
        .flag("quantize", "int8 weight-quantized serving (dequant correction folded from calibration)");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    let s10 = (args.f64("sparsity")? * 10.0).round() as u8;
    let controller_on = args.has_flag("controller");
    let degrade = args.has_flag("degrade");
    let quantize = args.has_flag("quantize");
    if degrade && !controller_on {
        bail!("--degrade needs --controller (variant switching is the controller's knob)");
    }
    if degrade && s10 == 0 {
        bail!("--degrade needs --sparsity > 0 (the degraded rung is the pruned+compensated variant)");
    }
    // Parse the fault plan before any model work so a malformed spec
    // fails fast.
    let chaos_spec = args.str("chaos");
    let chaos = if chaos_spec.trim().is_empty() {
        None
    } else {
        Some(crate::serve::FaultPlan::parse(&chaos_spec)?)
    };
    let mut coord = Coordinator::new()?;
    let popts = PruneOpts { sparsity: Sparsity::of(Scope::Both, s10), ..PruneOpts::default() };
    // Under --degrade the primary rung is always dense and the
    // pruned+compensated store becomes the controller's fallback rung;
    // otherwise --sparsity picks the single store served, as before.
    let pruned = if s10 == 0 { None } else { Some(coord.prune_job(cfg, &popts)?.weights) };
    let dense = coord.dense(cfg)?.clone();
    let (weights, fallback) = if degrade {
        (&dense, pruned.as_ref())
    } else if let Some(p) = &pruned {
        (p, None)
    } else {
        (&dense, None)
    };
    // --quantize: int8-quantize the ladder's cheapest store (the
    // pruned+compensated one when present, else dense) with the dequant
    // correction fitted on the same calibration moments pruning used.
    // Without --degrade the quantized store is served directly; with it,
    // the store becomes the controller's last degrade rung.
    let quant = if quantize {
        let base = pruned.as_ref().unwrap_or(&dense);
        coord.calib(cfg, &popts)?;
        let key = format!("{}@{}", cfg.name, popts.calib_batches);
        let stats = coord.calib_stats(&key);
        let kept = crate::compensate::mlp_kept_indices(cfg, &dense, stats, &popts)?;
        let (qs, report) =
            crate::compensate::quantize_weights_corrected(cfg, base, stats, &kept, popts.lambda)?;
        println!(
            "quantize: int8 weights ({:.2} MiB vs {:.2} MiB f32), dequant correction on {} \
             layer(s): residual mse {:.3e} → {:.3e}",
            qs.bytes() as f64 / (1024.0 * 1024.0),
            base.param_count() as f64 * 4.0 / (1024.0 * 1024.0),
            report.layers_corrected,
            report.mse_identity,
            report.mse_fitted
        );
        Some(qs)
    } else {
        None
    };
    let exec = coord.executor(cfg);
    let slo_p99_ms = args.f64("slo-p99-ms")?;
    let eopts = crate::serve::EngineOpts {
        workers: args.usize("workers")?,
        rate: args.f64("rate")?,
        requests: args.usize("requests")?,
        max_batch: args.usize("max-batch")?,
        max_wait: args.f64("max-wait-ms")? / 1e3,
        queue_cap: args.usize("queue-cap")?,
        exec_floor: args.f64("exec-floor")?,
        seed: args.usize("seed")? as u64,
        dispatch: crate::serve::DispatchPolicy::parse(&args.str("dispatch"))?,
        kv_block: args.usize("kv-block")?,
        kv_blocks: args.usize("kv-blocks")?,
        spike: args.f64("spike")?,
        slo_p99_ms,
        request_timeout: args.f64("request-timeout-ms")? / 1e3,
        max_retries: args.usize("retries")?,
        retry_backoff: args.f64("retry-backoff-ms")? / 1e3,
        chaos,
        controller: controller_on.then(|| crate::serve::ControllerOpts {
            slo_p99_ms,
            degrade,
            ..Default::default()
        }),
    };
    // The model (or an explicit --workload) picks the serving scenario: one
    // queueing/batching core, workload-specific synthesis and accounting.
    let wl_name = args.str("workload");
    let (label, stats) = match (cfg.kind, wl_name.as_str()) {
        (ModelKind::Vit, "auto" | "vision") => {
            let wl = crate::serve::VisionWorkload::new(cfg, crate::data::DATA_SEED)?;
            ("vision", serve_one(&exec, weights, fallback, quant.as_ref(), &wl, &eopts)?)
        }
        (ModelKind::Gpt, "auto" | "text") => {
            let wl = crate::serve::GptWorkload::new(cfg, crate::data::DATA_SEED)?;
            ("text", serve_one(&exec, weights, fallback, quant.as_ref(), &wl, &eopts)?)
        }
        (ModelKind::Gpt, "gen") => {
            let max_new = args.usize("max-new")?;
            if max_new == 0 || max_new > cfg.n_ctx {
                bail!("max-new must be in 1..={}, got {max_new}", cfg.n_ctx);
            }
            let shared = args.usize("shared-prefix")?;
            if shared > cfg.n_ctx {
                bail!("shared-prefix must be <= n_ctx {}, got {shared}", cfg.n_ctx);
            }
            let mut wl = crate::serve::GenWorkload::new(cfg, crate::data::DATA_SEED)?
                .with_max_new(max_new)
                .with_prefill_chunk(args.usize("prefill-chunk")?)
                .with_shared_prefix(shared);
            let decode = args.str("decode");
            if decode != "auto" {
                wl = wl.with_decode(DecodeMode::parse(&decode)?);
            }
            ("gen", serve_one(&exec, weights, fallback, quant.as_ref(), &wl, &eopts)?)
        }
        (kind, other) => bail!(
            "workload '{other}' does not fit model '{}' (kind {kind:?}; \
             expected auto|vision|text|gen)",
            cfg.name
        ),
    };
    println!(
        "served {}/{} {label} requests ({} shed) on {} worker(s), dispatch {}: \
         p50 {:.2}ms p95 {:.2}ms (queue p50 {:.2}ms, exec mean {:.2}ms) | \
         batch {:.1} → dispatch {:.1} over {} batches, {:.1} steps/req \
         (ttft p50 {:.2}ms, itl {:.2}ms) | {:.0} req/s, {:.0} tok/s",
        stats.served,
        eopts.requests,
        stats.shed,
        eopts.workers,
        eopts.dispatch.label(),
        stats.p50_ms,
        stats.p95_ms,
        stats.queue_p50_ms,
        stats.exec_mean_ms,
        stats.mean_batch,
        stats.mean_dispatch,
        stats.batches,
        stats.steps_mean,
        stats.first_p50_ms,
        stats.itl_mean_ms,
        stats.throughput_fps,
        stats.throughput_tps
    );
    if stats.kv_peak_bytes > 0 {
        println!(
            "kv pool: {:.0} B appended/step, peak {:.1} KiB, {} blocks held at end | \
             {} allocs, {} shared-block hits, {} CoW copies",
            stats.kv_bytes_per_step,
            stats.kv_peak_bytes as f64 / 1024.0,
            stats.kv_blocks_in_use,
            stats.kv_allocs,
            stats.kv_shared_hits,
            stats.kv_cow_copies
        );
    }
    if stats.failures + stats.retries + stats.timeouts + stats.worker_respawns > 0
        || eopts.chaos.is_some()
        || eopts.request_timeout > 0.0
    {
        println!(
            "faults: {} failed, {} retries, {} timeouts, {} worker respawn(s), \
             {} kv block(s) reclaimed",
            stats.failures,
            stats.retries,
            stats.timeouts,
            stats.worker_respawns,
            stats.kv_reclaimed_blocks
        );
    }
    // Post-run leak check: every block still referenced must be pinned by
    // the prefix registry (a deliberate cache). Anything beyond that was
    // leaked by an aborted request — fail the run so the CI smoke catches
    // it.
    if stats.kv_blocks_in_use > stats.kv_registered_blocks {
        bail!(
            "kv pool leak: {} block(s) in use at end but only {} registry-pinned",
            stats.kv_blocks_in_use,
            stats.kv_registered_blocks
        );
    }
    if eopts.controller.is_some() {
        let slo = if stats.slo_p99_ms > 0.0 {
            let verdict = if stats.p99_ms <= stats.slo_p99_ms { "met" } else { "MISSED" };
            format!(" vs SLO {:.0}ms ({verdict})", stats.slo_p99_ms)
        } else {
            String::new()
        };
        let switches: Vec<String> = stats
            .transitions
            .iter()
            .map(|tr| format!("{}→{}@{:.2}s", tr.from, tr.to, tr.t))
            .collect();
        let tv: Vec<String> = stats
            .time_in_variant_s
            .iter()
            .enumerate()
            .map(|(v, s)| format!("v{v} {s:.2}s"))
            .collect();
        let sv: Vec<String> = stats
            .served_by_variant
            .iter()
            .enumerate()
            .map(|(v, n)| format!("v{v} {n}"))
            .collect();
        println!(
            "controller: p99 {:.2}ms{slo} | variant switches [{}] | time-in-variant {} | \
             served-by-variant {}",
            stats.p99_ms,
            switches.join(", "),
            tv.join(" / "),
            sv.join(" / ")
        );
    }
    Ok(())
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("generate", "autoregressive greedy generation (gpt models)")
        .opt("model", "model name (gpt_*)", "gpt_s")
        .opt("sparsity", "joint sparsity 0.0-0.7", "0.5")
        .opt("prompts", "number of eval-stream prompts", "2")
        .opt("tokens", "tokens generated per prompt", "8")
        .opt("decode", "decode path: kv|prefill", "kv")
        .opt("kv-block", "KV pool: positions per block (0 = default)", "0")
        .opt("prefill-chunk", "max prompt tokens fed per step (0 = one-shot)", "0")
        .flag("verify", "run kv + prefill + the full forward and compare (non-zero exit on drift)");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    if cfg.kind != ModelKind::Gpt {
        bail!("generate needs a gpt model, got '{}' (kind {:?})", cfg.name, cfg.kind);
    }
    let tokens = args.usize("tokens")?;
    let prompts = args.usize("prompts")?;
    if tokens == 0 || tokens > cfg.n_ctx {
        bail!("tokens must be in 1..={}, got {tokens}", cfg.n_ctx);
    }
    if prompts == 0 {
        bail!("prompts must be > 0");
    }
    let req_mode = DecodeMode::parse(&args.str("decode"))?;
    let s10 = (args.f64("sparsity")? * 10.0).round() as u8;
    let mut coord = Coordinator::new()?;
    let weights = if s10 == 0 {
        coord.dense(cfg)?.clone()
    } else {
        let o = PruneOpts { sparsity: Sparsity::of(Scope::Both, s10), ..PruneOpts::default() };
        coord.prune_job(cfg, &o)?.weights
    };
    let exec = coord.executor(cfg);
    // Like the engine, collapse the requested mode to what the runtime can
    // actually dispatch (fixed-shape runtimes have no dec_* lowering).
    let fixed = exec.rt.prefers_fixed_shapes();
    let mode = req_mode.resolve(fixed);
    let mut pool_opts = KvPoolOpts::default();
    let kv_block = args.usize("kv-block")?;
    if kv_block > 0 {
        pool_opts.block = kv_block;
    }
    let chunk = args.usize("prefill-chunk")?;
    let plan = exec.decode_plan_opts(&weights, mode, pool_opts)?;
    let verify = args.has_flag("verify");
    // The cross-check plans are loop-invariant — resolve them once. On a
    // fixed-shape runtime both decode modes resolve to prefill-per-step, so
    // only the full-forward cross-check remains meaningful there.
    let (alt, fplan) = if verify {
        let other = match mode {
            DecodeMode::KvCache => DecodeMode::Prefill,
            DecodeMode::Prefill => DecodeMode::KvCache,
        }
        .resolve(fixed);
        let alt = if other != mode {
            Some((other, exec.decode_plan_with(&weights, other)?))
        } else {
            None
        };
        (alt, Some(exec.forward_plan(&weights)?))
    } else {
        (None, None)
    };
    let gen = crate::data::TextGen::new(crate::data::DATA_SEED);
    let min_prompt = crate::serve::default_min_prompt(cfg);
    for id in 0..prompts {
        let (ids, plen0) = gen.prompt(id as u64, cfg.n_ctx, min_prompt);
        // The final prediction is never appended, so prompt + tokens − 1
        // positions must fit in the context.
        let plen = plen0.min(cfg.n_ctx + 1 - tokens).max(1);
        let prompt = &ids[..plen];
        let t0 = std::time::Instant::now();
        let (preds, rows) = plan.greedy_chunked(prompt, tokens, chunk)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let checksum: f64 = rows.iter().flatten().map(|&v| v as f64).sum();
        println!(
            "prompt {id} (len {plen}) → {preds:?}  [{} decode: {ms:.2} ms total, \
             {:.2} ms/token, logits checksum {checksum:+.4}]",
            mode.label(),
            ms / tokens as f64
        );
        let mut maxd = 0.0f32;
        if let Some((other, alt)) = &alt {
            let (p2, r2) = alt.greedy(prompt, tokens)?;
            if preds != p2 {
                bail!(
                    "prompt {id}: {} vs {} token streams diverged: {preds:?} vs {p2:?}",
                    mode.label(),
                    other.label()
                );
            }
            for (a, b) in rows.iter().zip(&r2) {
                for (x, y) in a.iter().zip(b) {
                    maxd = maxd.max((x - y).abs());
                }
            }
            if maxd > 1e-4 {
                bail!("prompt {id}: kv vs prefill logits diverged by {maxd:.3e}");
            }
        }
        if let Some(fplan) = &fplan {
            // Cross-check the final step against the fused full forward on
            // the whole decoded sequence.
            let mut seq = prompt.to_vec();
            seq.extend_from_slice(&preds[..tokens - 1]);
            let mut padded = seq.clone();
            padded.resize(cfg.n_ctx, 0);
            let logits = fplan.run_gpt(&padded, 1)?;
            let last = &logits.data()[(seq.len() - 1) * cfg.vocab..seq.len() * cfg.vocab];
            let dec_last = rows.last().expect("at least one step");
            let mut fmax = 0.0f32;
            for (x, y) in dec_last.iter().zip(last) {
                fmax = fmax.max((x - y).abs());
            }
            if fmax > 1e-4 || crate::exec::argmax(last) != *preds.last().expect("step") {
                bail!("prompt {id}: decode vs full-prefill forward diverged by {fmax:.3e}");
            }
            println!(
                "  verify: {} decode == {}full prefill ✓ (max |Δlogit| {:.2e} across paths)",
                mode.label(),
                if alt.is_some() { "alternate decode == " } else { "" },
                maxd.max(fmax)
            );
        }
    }
    if let Some(s) = plan.pool_stats() {
        let (steps, bytes) = plan.kv_counters();
        println!(
            "kv pool: {steps} dispatches, {bytes} B appended ({:.0} B/step), peak {:.1} KiB, \
             {} shared-block hits, {} CoW copies",
            if steps == 0 { 0.0 } else { bytes as f64 / steps as f64 },
            s.peak_bytes() as f64 / 1024.0,
            s.shared_hits,
            s.cow_copies
        );
    }
    Ok(())
}

fn cmd_stats(argv: &[String]) -> Result<()> {
    let cmd = Command::new("stats", "Table-9 redundancy statistics")
        .opt("model", "model name", "vit_b")
        .opt("calib", "calibration batches", "16");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    let mut coord = Coordinator::new()?;
    let opts = PruneOpts { calib_batches: args.usize("calib")?, ..PruneOpts::default() };
    coord.dense(cfg)?;
    let stats = coord.calib(cfg, &opts)?;
    println!("layer | dim | eff.rank | ratio | k95 | k95-ratio | act.sparsity");
    for (l, ls) in stats.layers.iter().enumerate() {
        let red = crate::stats::redundancy(&ls.hidden.covariance());
        println!(
            "{l:5} | {:4} | {:8.1} | {:.3} | {:3} | {:.3}     | {:.2}",
            cfg.mlp, red.effective_rank, red.rank_ratio, red.k95, red.k95_ratio, ls.active.sparsity()
        );
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    let rt = crate::runtime::Runtime::from_default_dir()?;
    println!("artifacts: {} in manifest", rt.manifest().len());
    for cfg in crate::model::config::FAMILY {
        let dense = Sparsity::dense();
        println!(
            "{:6} {:?} d={} h={} L={} mlp={}  params {:.2}M flops {:.1}M  artifacts: {}",
            cfg.name,
            cfg.kind,
            cfg.d,
            cfg.heads,
            cfg.layers,
            cfg.mlp,
            crate::flops::params(cfg, dense) as f64 / 1e6,
            crate::flops::flops(cfg, dense) as f64 / 1e6,
            rt.has_artifact(&cfg.block_artifact(cfg.dh(), cfg.mlp, cfg.eval_batch())),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parsers() {
        assert_eq!(parse_scope("mlp").unwrap(), Scope::Mlp);
        assert!(parse_scope("bogus").is_err());
        assert_eq!(parse_method("corp").unwrap(), Method::Corp);
        assert!(parse_method("x").is_err());
        assert_eq!(parse_criterion("combined").unwrap(), Criterion::Mlp(MlpCriterion::Combined));
        assert_eq!(parse_criterion("variance").unwrap(), Criterion::Variance);
        assert_eq!(parse_criterion("obs").unwrap(), Criterion::Obs);
        assert_eq!(parse_criterion("energy").unwrap(), Criterion::Energy);
        assert!(parse_criterion("y").is_err());
        // Every zoo member's label round-trips through the parser.
        for crit in Criterion::zoo() {
            assert_eq!(parse_criterion(crit.label()).unwrap(), crit);
        }
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run_cli(&["nope".to_string()]).is_err());
    }

    #[test]
    fn bench_unknown_target_errors() {
        assert!(run_cli(&["bench".to_string(), "bogus".to_string()]).is_err());
    }

    #[test]
    fn generate_rejects_vit_models() {
        let err = run_cli(&["generate".into(), "--model".into(), "vit_t".into()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("gpt"), "{err}");
    }

    #[test]
    fn no_args_prints_usage() {
        run_cli(&[]).unwrap();
    }

    #[test]
    fn prune_budget_needs_both_scope() {
        let argv: Vec<String> =
            ["prune", "--model", "vit_t", "--scope", "mlp", "--flops-budget", "60"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = run_cli(&argv).unwrap_err().to_string();
        assert!(err.contains("--flops-budget"), "{err}");
    }

    #[test]
    fn serve_degrade_needs_controller() {
        let err = run_cli(&["serve".into(), "--model".into(), "vit_t".into(), "--degrade".into()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("--controller"), "{err}");
    }

    #[test]
    fn serve_rejects_malformed_chaos_spec() {
        let argv: Vec<String> = ["serve", "--model", "vit_t", "--chaos", "kill=zero@1"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = run_cli(&argv).unwrap_err().to_string();
        assert!(err.contains("--chaos"), "{err}");
    }

    #[test]
    fn serve_degrade_needs_sparsity() {
        let argv: Vec<String> =
            ["serve", "--model", "vit_t", "--controller", "--degrade", "--sparsity", "0"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = run_cli(&argv).unwrap_err().to_string();
        assert!(err.contains("--sparsity"), "{err}");
    }
}
