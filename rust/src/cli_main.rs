//! CLI dispatch for the `corp` binary.
//!
//! Subcommands:
//!   train   — train (or load) a dense checkpoint, print the loss curve tail
//!   prune   — run the CORP pipeline at a sparsity/method and report accuracy
//!   eval    — evaluate a checkpoint (dense or pruned) on the eval split
//!   serve   — run the dynamic batcher on a (pruned) model
//!   stats   — print the Table-9 redundancy statistics for a model
//!   list    — list models and artifact status

use anyhow::{bail, Context, Result};

use crate::coordinator::Coordinator;
use crate::model::{ModelConfig, Scope, Sparsity};
use crate::prune::{Method, PruneOpts};
use crate::rank::MlpCriterion;
use crate::util::cli::Command;

fn parse_scope(s: &str) -> Result<Scope> {
    Ok(match s {
        "mlp" => Scope::Mlp,
        "attn" => Scope::Attn,
        "both" => Scope::Both,
        _ => bail!("scope must be mlp|attn|both, got '{s}'"),
    })
}

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "corp" => Method::Corp,
        "naive" => Method::Naive,
        "grail" => Method::Grail,
        "vbp" => Method::Vbp,
        _ => bail!("method must be corp|naive|grail|vbp, got '{s}'"),
    })
}

fn parse_criterion(s: &str) -> Result<MlpCriterion> {
    Ok(match s {
        "act" => MlpCriterion::ActEnergy,
        "mag" => MlpCriterion::Magnitude,
        "combined" => MlpCriterion::Combined,
        "active" => MlpCriterion::ActiveProb,
        _ => bail!("criterion must be act|mag|combined|active, got '{s}'"),
    })
}

fn cfg_of(name: &str) -> Result<&'static ModelConfig> {
    ModelConfig::by_name(name).with_context(|| format!("unknown model '{name}'"))
}

pub fn run_cli(argv: &[String]) -> Result<()> {
    let Some(sub) = argv.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match sub {
        "train" => cmd_train(rest),
        "prune" => cmd_prune(rest),
        "serve" => cmd_serve(rest),
        "stats" => cmd_stats(rest),
        "bench" => cmd_bench(rest),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `corp help`)"),
    }
}

fn print_usage() {
    println!(
        "corp — CORP one-shot structured pruning (paper reproduction)\n\n\
         subcommands:\n  \
         train  --model vit_b [--steps N]        train/load the dense checkpoint\n  \
         prune  --model vit_b --scope both --sparsity 0.5 [--method corp] [--criterion combined]\n  \
         serve  --model vit_b --sparsity 0.5 [--workers 2] [--rate 200] [--dispatch auto]\n  \
         serve  --model gpt_s ...                same engine, text workload (prompt lengths)\n  \
         stats  --model vit_b                    Table-9 redundancy statistics\n  \
         bench  linalg|serve [--json] [--out PATH]  perf harnesses (BENCH_*.json)\n  \
         list                                    models + artifact status"
    );
}

fn cmd_bench(argv: &[String]) -> Result<()> {
    let cmd = Command::new("bench", "performance harness")
        .flag("json", "emit machine-readable results")
        .opt("out", "output path for --json (default BENCH_<target>.json)", "");
    let args = cmd.parse(argv)?;
    let target = args.positional().first().map(|s| s.as_str()).unwrap_or("linalg");
    let out = args.str("out");
    let out = if out.is_empty() { format!("BENCH_{target}.json") } else { out };
    let json = args.has_flag("json").then_some(out.as_str());
    match target {
        "linalg" => crate::bench_tables::linalg::bench_linalg(json),
        "serve" => crate::bench_tables::serve::bench_serve(json),
        other => bail!("unknown bench target '{other}' (available: linalg, serve)"),
    }
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train or load a dense checkpoint")
        .opt("model", "model name", "vit_b")
        .opt("steps", "training steps (0 = mode default)", "0");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    let mut coord = Coordinator::new()?;
    let steps = args.usize("steps")?;
    let w = if steps > 0 {
        let opts = crate::train::TrainOpts { steps, ..coord.train_opts(cfg) };
        crate::train::ensure_checkpoint(&coord.rt, cfg, &opts)?
    } else {
        coord.dense(cfg)?.clone()
    };
    match cfg.kind {
        crate::model::ModelKind::Vit => {
            let acc = coord.top1(cfg, &w, 99)?;
            println!("{}: {} params, top-1 {acc:.2}%", cfg.name, w.param_count());
        }
        crate::model::ModelKind::Gpt => {
            let exec = coord.executor(cfg);
            let gen = crate::data::TextGen::new(crate::data::DATA_SEED);
            let ppl = crate::eval::ppl_stitched(&exec, &w, &gen, 8)?;
            println!("{}: {} params, eval ppl {ppl:.3}", cfg.name, w.param_count());
        }
    }
    Ok(())
}

fn cmd_prune(argv: &[String]) -> Result<()> {
    let cmd = Command::new("prune", "run the one-shot pruning pipeline")
        .opt("model", "model name", "vit_b")
        .opt("scope", "mlp|attn|both", "both")
        .opt("sparsity", "0.0-0.7", "0.5")
        .opt("method", "corp|naive|grail|vbp", "corp")
        .opt("criterion", "act|mag|combined|active", "combined")
        .opt("lambda", "ridge strength", "0.01")
        .opt("calib", "calibration batches", "16");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    let scope = parse_scope(&args.str("scope"))?;
    let s10 = (args.f64("sparsity")? * 10.0).round() as u8;
    if s10 > 7 {
        bail!("sparsity must be <= 0.7 (artifact grid)");
    }
    let mut coord = Coordinator::new()?;
    let opts = PruneOpts {
        method: parse_method(&args.str("method"))?,
        criterion: parse_criterion(&args.str("criterion"))?,
        lambda: args.f64("lambda")?,
        calib_batches: args.usize("calib")?,
        ..PruneOpts::default()
    };
    let dense_acc = {
        let w = coord.dense(cfg)?.clone();
        coord.top1(cfg, &w, 99)?
    };
    let sp = Sparsity::of(scope, s10);
    let (acc, p, f, sections) = coord.accuracy_at(cfg, sp, opts.method, &opts)?;
    let pd = crate::flops::params(cfg, Sparsity::dense());
    let fd = crate::flops::flops(cfg, Sparsity::dense());
    println!(
        "{} {} s={:.1} [{}]: top-1 {acc:.2}% (dense {dense_acc:.2}%)  params {:.2}M (-{:.1}%)  flops {:.1}M (-{:.1}%)",
        cfg.name,
        scope.label(),
        s10 as f64 / 10.0,
        opts.method.label(),
        p as f64 / 1e6,
        crate::flops::reduction_pct(pd, p),
        f as f64 / 1e6,
        crate::flops::reduction_pct(fd, f),
    );
    println!(
        "pipeline: calibration {:.2}s  ranking {:.3}s  compensation {:.2}s",
        sections.get("calibration"),
        sections.get("ranking"),
        sections.get("compensation")
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "concurrent batched serving engine")
        .opt("model", "model name (vit_* → vision workload, gpt_* → text)", "vit_b")
        .opt("sparsity", "joint sparsity 0.0-0.7", "0.5")
        .opt("workers", "executor threads", "2")
        .opt("rate", "arrival rate req/s (0 = saturated)", "200")
        .opt("requests", "total requests", "256")
        .opt("max-batch", "max requests per batch", "16")
        .opt("max-wait-ms", "batching deadline, ms", "10")
        .opt("queue-cap", "queue bound (excess is shed)", "1024")
        .opt("exec-floor", "minimum per-batch execution time, seconds (load shaping)", "0")
        .opt("seed", "arrival-process seed", "7")
        .opt("dispatch", "batch dispatch shape: padded|exact|auto", "auto");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    let s10 = (args.f64("sparsity")? * 10.0).round() as u8;
    let mut coord = Coordinator::new()?;
    let opts = PruneOpts::default();
    let weights = if s10 == 0 {
        coord.dense(cfg)?.clone()
    } else {
        let o = PruneOpts { sparsity: Sparsity::of(Scope::Both, s10), ..opts };
        coord.prune_job(cfg, &o)?.weights
    };
    let exec = coord.executor(cfg);
    let eopts = crate::serve::EngineOpts {
        workers: args.usize("workers")?,
        rate: args.f64("rate")?,
        requests: args.usize("requests")?,
        max_batch: args.usize("max-batch")?,
        max_wait: args.f64("max-wait-ms")? / 1e3,
        queue_cap: args.usize("queue-cap")?,
        exec_floor: args.f64("exec-floor")?,
        seed: args.usize("seed")? as u64,
        dispatch: crate::serve::DispatchPolicy::parse(&args.str("dispatch"))?,
    };
    // The model picks the serving scenario: one queueing/batching core,
    // workload-specific request synthesis and accounting.
    let stats = match cfg.kind {
        crate::model::ModelKind::Vit => {
            let wl = crate::serve::VisionWorkload::new(cfg, crate::data::DATA_SEED)?;
            crate::serve::run_engine(&exec, &weights, &wl, &eopts)?
        }
        crate::model::ModelKind::Gpt => {
            let wl = crate::serve::GptWorkload::new(cfg, crate::data::DATA_SEED)?;
            crate::serve::run_engine(&exec, &weights, &wl, &eopts)?
        }
    };
    println!(
        "served {}/{} {} requests ({} shed) on {} worker(s), dispatch {}: \
         p50 {:.2}ms p95 {:.2}ms (queue p50 {:.2}ms, exec mean {:.2}ms) | \
         batch {:.1} → dispatch {:.1} over {} batches | {:.0} req/s, {:.0} tok/s",
        stats.served,
        eopts.requests,
        cfg.kind.workload_label(),
        stats.shed,
        eopts.workers,
        eopts.dispatch.label(),
        stats.p50_ms,
        stats.p95_ms,
        stats.queue_p50_ms,
        stats.exec_mean_ms,
        stats.mean_batch,
        stats.mean_dispatch,
        stats.batches,
        stats.throughput_fps,
        stats.throughput_tps
    );
    Ok(())
}

fn cmd_stats(argv: &[String]) -> Result<()> {
    let cmd = Command::new("stats", "Table-9 redundancy statistics")
        .opt("model", "model name", "vit_b")
        .opt("calib", "calibration batches", "16");
    let args = cmd.parse(argv)?;
    let cfg = cfg_of(&args.str("model"))?;
    let mut coord = Coordinator::new()?;
    let opts = PruneOpts { calib_batches: args.usize("calib")?, ..PruneOpts::default() };
    coord.dense(cfg)?;
    let stats = coord.calib(cfg, &opts)?;
    println!("layer | dim | eff.rank | ratio | k95 | k95-ratio | act.sparsity");
    for (l, ls) in stats.layers.iter().enumerate() {
        let red = crate::stats::redundancy(&ls.hidden.covariance());
        println!(
            "{l:5} | {:4} | {:8.1} | {:.3} | {:3} | {:.3}     | {:.2}",
            cfg.mlp, red.effective_rank, red.rank_ratio, red.k95, red.k95_ratio, ls.active.sparsity()
        );
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    let rt = crate::runtime::Runtime::from_default_dir()?;
    println!("artifacts: {} in manifest", rt.manifest().len());
    for cfg in crate::model::config::FAMILY {
        let dense = Sparsity::dense();
        println!(
            "{:6} {:?} d={} h={} L={} mlp={}  params {:.2}M flops {:.1}M  artifacts: {}",
            cfg.name,
            cfg.kind,
            cfg.d,
            cfg.heads,
            cfg.layers,
            cfg.mlp,
            crate::flops::params(cfg, dense) as f64 / 1e6,
            crate::flops::flops(cfg, dense) as f64 / 1e6,
            rt.has_artifact(&cfg.block_artifact(cfg.dh(), cfg.mlp, cfg.eval_batch())),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parsers() {
        assert_eq!(parse_scope("mlp").unwrap(), Scope::Mlp);
        assert!(parse_scope("bogus").is_err());
        assert_eq!(parse_method("corp").unwrap(), Method::Corp);
        assert!(parse_method("x").is_err());
        assert_eq!(parse_criterion("combined").unwrap(), MlpCriterion::Combined);
        assert!(parse_criterion("y").is_err());
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run_cli(&["nope".to_string()]).is_err());
    }

    #[test]
    fn bench_unknown_target_errors() {
        assert!(run_cli(&["bench".to_string(), "bogus".to_string()]).is_err());
    }

    #[test]
    fn no_args_prints_usage() {
        run_cli(&[]).unwrap();
    }
}
