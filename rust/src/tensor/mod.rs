//! Dense row-major f32 tensor.
//!
//! Deliberately small: the coordinator only needs shapes, element access,
//! column/channel gathering (for pruning index sets), reshapes and simple
//! reductions. Heavy math lives in `linalg` on plain `&[f32]` views.

use std::fmt;

/// Dense row-major tensor of f32 values.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(len={})", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape {shape:?} vs len {}", data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows when viewed as a 2-D [rows, cols] matrix (requires ndim>=1).
    pub fn rows(&self) -> usize {
        self.len() / self.cols()
    }

    /// Trailing dimension.
    pub fn cols(&self) -> usize {
        *self.shape.last().expect("tensor has no dims")
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Flatten all leading dims: [a, b, ..., c] -> [a*b*..., c].
    pub fn flatten_2d(self) -> Self {
        let c = self.cols();
        let r = self.len() / c;
        self.reshape(&[r, c])
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.cols() + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    /// Gather a subset of trailing-dim columns: `out[..., k] = self[..., idx[k]]`.
    pub fn gather_cols(&self, idx: &[usize]) -> Tensor {
        let c = self.cols();
        let r = self.len() / c;
        let mut out = Vec::with_capacity(r * idx.len());
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            for &j in idx {
                out.push(row[j]);
            }
        }
        let mut shape = self.shape.clone();
        *shape.last_mut().unwrap() = idx.len();
        Tensor::from_vec(&shape, out)
    }

    /// Gather rows of a 2-D matrix: `out[k, :] = self[idx[k], :]`.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let c = self.cols();
        let mut out = Vec::with_capacity(idx.len() * c);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
        Tensor::from_vec(&[idx.len(), c], out)
    }

    /// Transpose a 2-D matrix.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.rows(), self.cols());
        let mut out = vec![0.0; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::from_vec(&[c, r], out)
    }

    /// Concatenate along the trailing dimension.
    pub fn concat_cols(a: &Tensor, b: &Tensor) -> Tensor {
        let (ca, cb) = (a.cols(), b.cols());
        let r = a.len() / ca;
        assert_eq!(r, b.len() / cb, "row mismatch");
        let mut out = Vec::with_capacity(r * (ca + cb));
        for i in 0..r {
            out.extend_from_slice(&a.data[i * ca..(i + 1) * ca]);
            out.extend_from_slice(&b.data[i * cb..(i + 1) * cb]);
        }
        let mut shape = a.shape.clone();
        *shape.last_mut().unwrap() = ca + cb;
        Tensor::from_vec(&shape, out)
    }

    /// Slice of the leading dimension: rows [start, end).
    pub fn slice_rows(&self, start: usize, end: usize) -> Tensor {
        let outer = self.shape[0];
        assert!(start <= end && end <= outer);
        let inner: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = end - start;
        Tensor::from_vec(&shape, self.data[start * inner..end * inner].to_vec())
    }

    /// Elementwise squared L2 distance to another tensor (same shape).
    pub fn sq_dist(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt()
    }

    /// Max absolute difference to another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data.iter().zip(&other.data).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_shape() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at2(1, 2), 6.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1., 2., 3.]);
    }

    #[test]
    fn gather_cols_3d() {
        // [2, 2, 3] tensor; gather trailing cols [2, 0]
        let t = Tensor::from_vec(&[2, 2, 3], (0..12).map(|v| v as f32).collect());
        let g = t.gather_cols(&[2, 0]);
        assert_eq!(g.shape(), &[2, 2, 2]);
        assert_eq!(g.data(), &[2., 0., 5., 3., 8., 6., 11., 9.]);
    }

    #[test]
    fn gather_rows_2d() {
        let t = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.data(), &[20., 21., 0., 1.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose2().transpose2();
        assert_eq!(t, tt);
    }

    #[test]
    fn concat_then_gather_recovers() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 1], vec![9., 8.]);
        let c = Tensor::concat_cols(&a, &b);
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.gather_cols(&[0, 1]).data(), a.data());
        assert_eq!(c.gather_cols(&[2]).data(), b.data());
    }

    #[test]
    fn slice_rows_leading() {
        let t = Tensor::from_vec(&[4, 2], (0..8).map(|v| v as f32).collect());
        let s = t.slice_rows(1, 3);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2., 3., 4., 5.]);
    }

    #[test]
    fn distances() {
        let a = Tensor::from_vec(&[2], vec![0., 3.]);
        let b = Tensor::from_vec(&[2], vec![4., 3.]);
        assert!((a.sq_dist(&b) - 16.0).abs() < 1e-12);
        assert!((a.max_abs_diff(&b) - 4.0).abs() < 1e-7);
        assert!((b.frob_norm() - 5.0).abs() < 1e-7);
    }

    #[test]
    fn flatten_2d_merges_leading() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.flatten_2d().shape(), &[6, 4]);
    }
}
