"""AOT registry / manifest checks (no lowering — fast)."""

import json
from pathlib import Path

from compile import aot
from compile import model as M


def test_registry_names_unique():
    reg = aot.build_registry()
    names = [e["name"] for e in reg.entries]
    assert len(set(names)) == len(names)


def test_registry_covers_all_models():
    reg = aot.build_registry()
    names = {e["name"] for e in reg.entries}
    for cfg in M.CONFIGS.values():
        b = aot.GPT_B if cfg.kind == "gpt" else aot.EVAL_B
        assert f"embed_{cfg.name}_b{b}" in names
        assert f"head_{cfg.name}_b{b}" in names
        assert f"blockcap_{cfg.name}_b{b}" in names
        assert f"train_{cfg.name}" in names
        assert f"evloss_{cfg.name}" in names
        assert f"block_{cfg.name}_q{cfg.dh}_o{cfg.mlp}_b{b}" in names


def test_registry_has_joint_sparsity_grid():
    reg = aot.build_registry()
    names = {e["name"] for e in reg.entries}
    for cfg in [M.CONFIGS["vit_l"], M.CONFIGS["vit_h"]]:
        for s in range(1, 8):
            q = M.keep_count(cfg.dh, s)
            o = M.keep_count(cfg.mlp, s)
            assert f"block_{cfg.name}_q{q}_o{o}_b{aot.EVAL_B}" in names, (cfg.name, s)
            assert f"block_{cfg.name}_q{cfg.dh}_o{o}_b{aot.EVAL_B}" in names
            assert f"block_{cfg.name}_q{q}_o{cfg.mlp}_b{aot.EVAL_B}" in names


def test_block_inputs_order_matches_param_spec():
    cfg = M.CONFIGS["vit_t"]
    ins = aot.block_inputs(cfg, cfg.dh, cfg.mlp, 4)
    assert ins[0][0] == "x"
    expect = [n for n, _ in M.block_param_spec(cfg, cfg.dh, cfg.mlp)]
    assert [n for n, _, _ in ins[1:]] == expect


def test_train_entry_io_symmetry():
    reg = aot.build_registry()
    entry = next(e for e in reg.entries if e["name"] == "train_vit_t")
    cfg = M.CONFIGS["vit_t"]
    n = len(M.param_spec(cfg))
    # inputs: tokens, labels, lrs, t0, params…, adam_m…, adam_v…
    assert len(entry["inputs"]) == 4 + 3 * n
    # chunked data: leading K axis on tokens/labels and lrs[K]
    assert entry["inputs"][0][1][0] == aot.TRAIN_CHUNK
    assert entry["inputs"][2][1] == (aot.TRAIN_CHUNK,)
    # outputs: params…, adam_m…, adam_v…, losses
    assert len(entry["out_names"]) == 3 * n + 1
    assert entry["out_names"][-1] == "losses"
    in_param_names = [i[0] for i in entry["inputs"][4 : 4 + n]]
    assert entry["out_names"][:n] == in_param_names


def test_manifest_file_valid_if_present():
    path = Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not path.exists():
        return  # `make artifacts` not run yet
    data = json.loads(path.read_text())
    assert "artifacts" in data
    for art in data["artifacts"]:
        assert set(art) >= {"name", "file", "inputs", "outputs"}
        for i in art["inputs"]:
            assert i["dtype"] in ("f32", "i32")
            assert all(isinstance(s, int) for s in i["shape"])
