"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the causal flag / block sizes) so the kernels
are exercised across uneven grids, single-row inputs, and pruned QK dims.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, gram, layernorm, mlp, ref

RTOL = 2e-5
ATOL = 2e-5


def _arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 40),
    d=st.integers(1, 70),
    block=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(n, d, block, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, n, d)
    g = _arr(rng, d)
    b = _arr(rng, d)
    got = layernorm.layernorm(x, g, b, block_rows=block)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 24),
    d=st.integers(1, 48),
    o=st.integers(1, 96),
    block=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_matches_ref(n, d, o, block, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, n, d)
    w1 = _arr(rng, d, o, scale=0.3)
    b1 = _arr(rng, o, scale=0.3)
    w2 = _arr(rng, o, d, scale=0.3)
    b2 = _arr(rng, d, scale=0.3)
    got = mlp.mlp(x, w1, b1, w2, b2, block_hidden=block)
    want = ref.mlp(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 24),
    d=st.integers(1, 48),
    o=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_hidden_matches_ref(n, d, o, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, n, d)
    w1 = _arr(rng, d, o, scale=0.3)
    b1 = _arr(rng, o, scale=0.3)
    got = mlp.mlp_hidden(x, w1, b1)
    want = ref.mlp_hidden(x, w1, b1)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 40),
    dqk=st.integers(1, 40),
    dv=st.integers(1, 40),
    causal=st.booleans(),
    bq=st.sampled_from([4, 16, 64]),
    bk=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(n, dqk, dv, causal, bq, bk, seed):
    rng = np.random.default_rng(seed)
    q = _arr(rng, n, dqk)
    k = _arr(rng, n, dqk)
    v = _arr(rng, n, dv)
    scale = 1.0 / np.sqrt(max(dqk, 1))
    got = attention.attention(q, k, v, scale, causal=causal, block_q=bq, block_k=bk)
    want = ref.attention(q, k, v, scale, causal=causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attention_pruned_qk_dim_smaller_than_v():
    """The CORP shape: q/k pruned to d' < dv, scale from the dense head."""
    rng = np.random.default_rng(0)
    q = _arr(rng, 17, 13)
    k = _arr(rng, 17, 13)
    v = _arr(rng, 17, 32)
    scale = 1.0 / np.sqrt(32)
    got = attention.attention(q, k, v, scale)
    want = ref.attention(q, k, v, scale)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_multi_head_attention_vmap():
    rng = np.random.default_rng(1)
    q = _arr(rng, 4, 17, 8)
    k = _arr(rng, 4, 17, 8)
    v = _arr(rng, 4, 17, 16)
    got = attention.multi_head_attention(q, k, v, 0.35)
    want = jnp.stack([ref.attention(q[i], k[i], v[i], 0.35) for i in range(4)])
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 60),
    d=st.integers(1, 48),
    bd=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(n, d, bd, bn, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, n, d)
    got = gram.gram(x, block_d=bd, block_n=bn)
    want = ref.gram(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gram_symmetric():
    rng = np.random.default_rng(2)
    x = _arr(rng, 33, 20)
    g = np.asarray(gram.gram(x))
    np.testing.assert_allclose(g, g.T, rtol=1e-6, atol=1e-6)


def test_attention_rows_sum_via_uniform_v():
    """With v = all-ones, output must be exactly ones (softmax normalizes)."""
    rng = np.random.default_rng(3)
    q = _arr(rng, 9, 5)
    k = _arr(rng, 9, 5)
    v = jnp.ones((9, 7), jnp.float32)
    out = attention.attention(q, k, v, 0.4)
    np.testing.assert_allclose(out, np.ones((9, 7)), rtol=1e-5, atol=1e-5)


def test_causal_first_row_equals_v0():
    """Causal attention at position 0 can only attend to key 0."""
    rng = np.random.default_rng(4)
    q = _arr(rng, 8, 6)
    k = _arr(rng, 8, 6)
    v = _arr(rng, 8, 6)
    out = attention.attention(q, k, v, 0.3, causal=True)
    np.testing.assert_allclose(out[0], v[0], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_layernorm_zero_variance_row(dtype):
    """Constant rows must not produce NaNs (eps guards the rsqrt)."""
    x = jnp.full((3, 10), 2.5, dtype)
    g = jnp.ones((10,), dtype)
    b = jnp.zeros((10,), dtype)
    out = layernorm.layernorm(x, g, b)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, np.zeros((3, 10)), atol=1e-3)
