"""Layer-2 model checks: shapes, pallas/ref path equivalence, train step."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def tiny_cfg():
    return M.ModelConfig("tiny_test", "vit", d=24, heads=3, layers=2, mlp=48, n_ctx=17)


def tiny_gpt_cfg():
    return M.ModelConfig("tiny_gpt", "gpt", d=16, heads=2, layers=2, mlp=32, n_ctx=12, vocab=11)


def init_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in M.param_spec(cfg):
        if name.endswith(".g"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b", ".bq", ".bk", ".bv", ".bo")) or name.endswith("bias"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.normal(size=shape) * 0.05, jnp.float32))
    return out


def test_param_spec_counts():
    cfg = tiny_cfg()
    spec = M.param_spec(cfg)
    # 4 embed + 16/block + 4 head
    assert len(spec) == 4 + 16 * cfg.layers + 4
    names = [n for n, _ in spec]
    assert len(set(names)) == len(names)


def test_keep_count_properties():
    for dim in [32, 384, 768, 1280]:
        prev = dim + 1
        for s in range(0, 8):
            k = M.keep_count(dim, s)
            assert 1 <= k <= dim
            assert k <= prev  # monotone in sparsity
            prev = k
        assert M.keep_count(dim, 0) == dim
        assert abs(M.keep_count(dim, 5) - dim / 2) <= 1


def test_vit_forward_shapes():
    cfg = tiny_cfg()
    params = init_params(cfg)
    tokens = jnp.asarray(np.random.default_rng(1).normal(size=(cfg.patches, cfg.patch_dim)), jnp.float32)
    logits = M.forward_one(cfg, params, tokens)
    assert logits.shape == (cfg.classes,)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_gpt_forward_shapes():
    cfg = tiny_gpt_cfg()
    params = init_params(cfg)
    ids = jnp.arange(cfg.n_ctx, dtype=jnp.int32) % cfg.vocab
    logits = M.forward_one(cfg, params, ids)
    assert logits.shape == (cfg.n_ctx, cfg.vocab)


def test_pallas_and_ref_paths_agree():
    """The serving path (pallas kernels) must equal the training path (ref)."""
    cfg = tiny_cfg()
    params = init_params(cfg, seed=3)
    tokens = jnp.asarray(np.random.default_rng(2).normal(size=(cfg.patches, cfg.patch_dim)), jnp.float32)
    lp = M.forward_one(cfg, params, tokens, use_pallas=True)
    lr_ = M.forward_one(cfg, params, tokens, use_pallas=False)
    np.testing.assert_allclose(lp, lr_, rtol=2e-4, atol=2e-4)


def test_gpt_causality():
    """Changing a future token must not change earlier logits."""
    cfg = tiny_gpt_cfg()
    params = init_params(cfg, seed=4)
    ids = jnp.arange(cfg.n_ctx, dtype=jnp.int32) % cfg.vocab
    base = M.forward_one(cfg, params, ids)
    ids2 = ids.at[-1].set((ids[-1] + 1) % cfg.vocab)
    pert = M.forward_one(cfg, params, ids2)
    np.testing.assert_allclose(base[:-1], pert[:-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[-1], pert[-1])


def test_block_capture_outputs():
    cfg = tiny_cfg()
    params = init_params(cfg, seed=5)
    names = [n for n, _ in M.block_param_spec(cfg, cfg.dh, cfg.mlp)]
    block_p = {n: p for (pn, _), p in zip(M.param_spec(cfg), params) for n in [pn]}
    p = {n: block_p[f"blocks.0.{n}"] for n in names}
    x = jnp.asarray(np.random.default_rng(6).normal(size=(cfg.n_ctx, cfg.d)), jnp.float32)
    y, hidden, q, k = M.block_one(x, p, cfg, causal=False, capture=True)
    assert y.shape == (cfg.n_ctx, cfg.d)
    assert hidden.shape == (cfg.n_ctx, cfg.mlp)
    assert q.shape == (cfg.heads, cfg.n_ctx, cfg.dh)
    assert k.shape == (cfg.heads, cfg.n_ctx, cfg.dh)
    # capture path must not perturb the block output
    y2 = M.block_one(x, p, cfg, causal=False, capture=False)
    np.testing.assert_allclose(y, y2, rtol=1e-6, atol=1e-6)


def test_train_step_decreases_loss():
    cfg = tiny_cfg()
    params = init_params(cfg, seed=7)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(8)
    tokens = jnp.asarray(rng.normal(size=(8, cfg.patches, cfg.patch_dim)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.classes, size=(8,)), jnp.int32)
    losses = []
    step = jax.jit(lambda i, l, lr, t, p, mm, vv: M.train_step(cfg, i, l, lr, t, p, mm, vv))
    for it in range(20):
        params, m, v, loss = step(tokens, labels, jnp.float32(3e-3), jnp.float32(it + 1), params, m, v)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses


def test_train_chunk_matches_sequential_steps():
    """One train_chunk call == K sequential train_step calls (same data)."""
    cfg = tiny_cfg()
    params = init_params(cfg, seed=9)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(10)
    k = 4
    tokens = jnp.asarray(rng.normal(size=(k, 4, cfg.patches, cfg.patch_dim)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.classes, size=(k, 4)), jnp.int32)
    lrs = jnp.asarray([1e-3, 2e-3, 1e-3, 5e-4], jnp.float32)
    cp, cm, cv, losses = M.train_chunk(cfg, tokens, labels, lrs, jnp.float32(1.0), params, m, v)
    sp, sm, sv = params, m, v
    seq_losses = []
    for i in range(k):
        sp, sm, sv, loss = M.train_step(cfg, tokens[i], labels[i], lrs[i], jnp.float32(i + 1), sp, sm, sv)
        seq_losses.append(float(loss))
    np.testing.assert_allclose(np.asarray(losses), np.asarray(seq_losses), rtol=1e-5, atol=1e-5)
    # scan-vs-unrolled f32 accumulation differs at ~1e-4 after Adam rescaling
    for a, b in zip(cp, sp):
        np.testing.assert_allclose(a, b, rtol=3e-3, atol=3e-4)


def test_pruned_block_shapes_run():
    """Pruned wq/wk/w1/w2 shapes flow through block_one."""
    cfg = tiny_cfg()
    dqk, o = 5, 20
    rng = np.random.default_rng(9)
    p = {}
    for name, shape in M.block_param_spec(cfg, dqk, o):
        p[name] = (
            jnp.ones(shape, jnp.float32)
            if name.endswith(".g")
            else jnp.asarray(rng.normal(size=shape) * 0.05, jnp.float32)
        )
    x = jnp.asarray(rng.normal(size=(cfg.n_ctx, cfg.d)), jnp.float32)
    y = M.block_one(x, p, cfg, causal=False)
    assert y.shape == (cfg.n_ctx, cfg.d)
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("name", list(M.CONFIGS))
def test_family_configs_consistent(name):
    cfg = M.CONFIGS[name]
    assert cfg.d % cfg.heads == 0
    assert cfg.dh == 32
    if cfg.kind == "vit":
        assert cfg.n_ctx == cfg.patches + 1
