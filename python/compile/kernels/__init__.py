"""Layer-1 Pallas kernels (build-time only).

Every kernel is written as a Pallas kernel with `interpret=True` so it lowers
to plain HLO ops executable by the CPU PJRT client (real-TPU Mosaic
custom-calls cannot run there; see DESIGN.md §Hardware-Adaptation). Each
kernel has a pure-jnp oracle in `ref.py` that pytest compares against.
"""

from . import attention, layernorm, mlp, gram, ref  # noqa: F401
