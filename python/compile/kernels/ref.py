"""Pure-jnp oracles for every Layer-1 Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py`
asserts allclose between each Pallas kernel (interpret mode) and the oracle
over a sweep of shapes and dtypes.
"""

import jax.numpy as jnp


def layernorm(x, gamma, beta, eps: float = 1e-6):
    """LayerNorm over the trailing dimension."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gelu(x):
    """Tanh-approximate GELU (jax.nn.gelu(approximate=True)).

    The erf-based exact GELU lowers to the `erf` HLO opcode, which the
    xla_extension 0.5.1 text parser rejects — the tanh form uses only
    classic opcodes (multiply/add/tanh) and parses cleanly.
    """
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def mlp(x, w1, b1, w2, b2):
    """Transformer MLP: GELU(x W1 + b1) W2 + b2.

    x: [n, d], w1: [d, o], b1: [o], w2: [o, d], b2: [d].
    """
    h = gelu(x @ w1 + b1)
    return h @ w2 + b2


def mlp_hidden(x, w1, b1):
    """The hidden activation the CORP calibration pass captures."""
    return gelu(x @ w1 + b1)


def attention(q, k, v, scale: float, causal: bool = False):
    """Softmax attention for one head.

    q, k: [n, dqk] (dqk may be pruned below dv), v: [n, dv].
    `scale` multiplies the logits; CORP keeps 1/sqrt(d_h of the dense model)
    after pruning so compensated logits stay on the original scale.
    """
    logits = (q @ k.T) * scale
    if causal:
        n = q.shape[0]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def gram(x):
    """Gram matrix XᵀX over the leading (sample) axis. x: [n, d] -> [d, d]."""
    return x.T @ x
