"""Pallas layernorm kernel.

Tiles rows of the token matrix; each grid step normalizes a row block over
the feature axis in VMEM. On TPU the row tile would be sized so that
(block_rows × d × 4B) plus the γ/β vectors fit VMEM; in interpret mode the
same BlockSpec structure runs on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps grids exact)."""
    for cand in range(min(n, target), 0, -1):
        if n % cand == 0:
            return cand
    return n


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def layernorm(x, gamma, beta, eps: float = 1e-6, block_rows: int = 32):
    """LayerNorm over the trailing axis. x: [n, d]; gamma/beta: [d]."""
    n, d = x.shape
    bn = _pick_block(n, block_rows)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)
