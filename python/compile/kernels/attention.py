"""Pallas attention kernel (flash-style streaming softmax).

One grid step per query block; keys/values are consumed in tiles with a
running max / running denominator (the numerically stable flash recurrence),
so the full [n, n] logit matrix never materializes.

CORP-specific shape: q and k may have a *pruned* head dimension d'_qk smaller
than v's head dimension d_v; the logit `scale` stays 1/sqrt(d_h of the dense
model) so compensated logits live on the original scale (§3.4).

TPU mapping: q/k/v tiles sized for VMEM; the QKᵀ tile and the PV tile are
both MXU matmuls; the paper's CUDA framing (threadblocks over heads) becomes
the Pallas grid over (head, query-block). interpret=True for CPU execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .layernorm import _pick_block

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, block_k, n_keys, q_offset_blocks):
    q = q_ref[...] * jnp.asarray(scale, q_ref.dtype)  # [bq, dqk]
    bq = q.shape[0]
    dv = v_ref.shape[-1]
    n_kb = n_keys // block_k
    # Read the grid coordinate outside the fori_loop: interpret-mode lowering
    # cannot substitute program_id inside control-flow bodies.
    pid = pl.program_id(0)

    def body(kb, carry):
        acc, m_run, l_run = carry
        k_tile = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        logits = q @ k_tile.T  # [bq, block_k]
        if causal:
            qi = pid * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            qi = qi + q_offset_blocks * bq
            kj = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            logits = jnp.where(qi >= kj, logits, _NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, dv), q.dtype)
    m0 = jnp.full((bq,), _NEG_INF, q.dtype)
    l0 = jnp.zeros((bq,), q.dtype)
    acc, _, l_run = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))
    o_ref[...] = acc / l_run[:, None]


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q", "block_k"))
def attention(q, k, v, scale: float, causal: bool = False, block_q: int = 64, block_k: int = 64):
    """Single-head attention. q,k: [n, dqk]; v: [n, dv] -> [n, dv]."""
    n, _ = q.shape
    dv = v.shape[-1]
    bq = _pick_block(n, block_q)
    bk = _pick_block(n, block_k)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, block_k=bk, n_keys=n, q_offset_blocks=0
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, q.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((n, k.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((n, dv), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), q.dtype),
        interpret=True,
    )(q, k, v)


def multi_head_attention(q, k, v, scale: float, causal: bool = False):
    """vmap the single-head kernel over a leading heads axis.

    q, k: [h, n, dqk]; v: [h, n, dv] -> [h, n, dv].
    """
    return jax.vmap(lambda qq, kk, vv: attention(qq, kk, vv, scale, causal))(q, k, v)
