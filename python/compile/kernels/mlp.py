"""Pallas fused-MLP kernel: GELU(x·W1 + b1)·W2 + b2.

The grid tiles the *hidden* dimension — the axis CORP prunes. Each grid step
computes one hidden tile's contribution `gelu(x W1[:, t] + b1[t]) W2[t, :]`
and accumulates into the output block, so removing hidden channels is
literally removing grid steps. The bias b2 is added on the first step.

TPU mapping: a hidden tile of 128 keeps both weight tiles MXU-shaped
(d×128 and 128×d bf16 blocks) and the x row-block resident in VMEM across
steps; interpret=True runs the identical schedule on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .layernorm import _pick_block


def _gelu(x):
    # Tanh-approximate GELU — the erf HLO opcode is rejected by the
    # xla_extension 0.5.1 text parser (see ref.gelu).
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    t = pl.program_id(0)
    h = _gelu(x_ref[...] @ w1_ref[...] + b1_ref[...])
    contrib = h @ w2_ref[...]

    @pl.when(t == 0)
    def _init():
        o_ref[...] = contrib + b2_ref[...]

    @pl.when(t != 0)
    def _acc():
        o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("block_hidden",))
def mlp(x, w1, b1, w2, b2, block_hidden: int = 128):
    """Fused MLP. x: [n, d], w1: [d, o], b1: [o], w2: [o, d], b2: [d]."""
    n, d = x.shape
    o = w1.shape[1]
    bo = _pick_block(o, block_hidden)
    return pl.pallas_call(
        _mlp_kernel,
        grid=(o // bo,),
        in_specs=[
            pl.BlockSpec((n, d), lambda t: (0, 0)),
            pl.BlockSpec((d, bo), lambda t: (0, t)),
            pl.BlockSpec((bo,), lambda t: (t,)),
            pl.BlockSpec((bo, d), lambda t: (t, 0)),
            pl.BlockSpec((d,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((n, d), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


def _hidden_kernel(x_ref, w1_ref, b1_ref, o_ref):
    o_ref[...] = _gelu(x_ref[...] @ w1_ref[...] + b1_ref[...])


@functools.partial(jax.jit, static_argnames=("block_hidden",))
def mlp_hidden(x, w1, b1, block_hidden: int = 128):
    """Hidden activation GELU(x W1 + b1) — what calibration captures."""
    n, d = x.shape
    o = w1.shape[1]
    bo = _pick_block(o, block_hidden)
    return pl.pallas_call(
        _hidden_kernel,
        grid=(o // bo,),
        in_specs=[
            pl.BlockSpec((n, d), lambda t: (0, 0)),
            pl.BlockSpec((d, bo), lambda t: (0, t)),
            pl.BlockSpec((bo,), lambda t: (t,)),
        ],
        out_specs=pl.BlockSpec((n, bo), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((n, o), x.dtype),
        interpret=True,
    )(x, w1, b1)
