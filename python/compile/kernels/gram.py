"""Pallas Gram-accumulation kernel: G = XᵀX over the sample axis.

This is the calibration-statistics offload: the covariance blocks of CORP's
ridge systems (Eq. 10) are assembled from Gram matrices of activation
batches. The grid tiles (row-block i, col-block j, sample-block t) and
accumulates partial products into the [d, d] output, mirroring how a TPU
would keep a G tile resident in VMEM while streaming X from HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .layernorm import _pick_block


def _gram_kernel(x_i_ref, x_j_ref, o_ref):
    t = pl.program_id(2)
    part = x_i_ref[...].T @ x_j_ref[...]

    @pl.when(t == 0)
    def _init():
        o_ref[...] = part

    @pl.when(t != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_d", "block_n"))
def gram(x, block_d: int = 128, block_n: int = 128):
    """Gram matrix XᵀX. x: [n, d] -> [d, d]."""
    n, d = x.shape
    bd = _pick_block(d, block_d)
    bn = _pick_block(n, block_n)
    return pl.pallas_call(
        _gram_kernel,
        grid=(d // bd, d // bd, n // bn),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, t: (t, i)),
            pl.BlockSpec((bn, bd), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), x.dtype),
        interpret=True,
    )(x, x)
