"""Layer-2 JAX model definitions (build time only).

Weights are *arguments* of every graph — the Rust coordinator owns the
weights, so one HLO artifact per shape configuration serves any depth and
any weight state (dense, pruned, compensated). Canonical parameter order is
defined by `param_spec` and exported through the manifest.

Inference / calibration graphs call the Layer-1 Pallas kernels; the training
step uses the pure-jnp references (`kernels/ref.py`) because `pallas_call`
has no autodiff rule — the serving path is the kernel path, the one-time
training path is plain L2 JAX. Both are asserted equal by pytest.
"""

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention as katt
from .kernels import layernorm as kln
from .kernels import mlp as kmlp
from .kernels import ref


# --------------------------------------------------------------------------
# Configs (mirrored by rust/src/model/config.rs)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str  # "vit" | "gpt"
    d: int
    heads: int
    layers: int
    mlp: int
    n_ctx: int  # vit: patches + 1 (CLS); gpt: sequence length
    patches: int = 16
    patch_dim: int = 48  # 4x4 patches, 3 channels
    classes: int = 16
    vocab: int = 96

    @property
    def dh(self) -> int:
        assert self.d % self.heads == 0
        return self.d // self.heads


# The scaled DeiT family (CPU-feasible; see DESIGN.md §Substitutions) plus a
# char-level GPT standing in for OPT.
CONFIGS = {
    "vit_t": ModelConfig("vit_t", "vit", d=96, heads=3, layers=6, mlp=384, n_ctx=17),
    "vit_s": ModelConfig("vit_s", "vit", d=128, heads=4, layers=8, mlp=512, n_ctx=17),
    "vit_b": ModelConfig("vit_b", "vit", d=192, heads=6, layers=10, mlp=768, n_ctx=17),
    "vit_l": ModelConfig("vit_l", "vit", d=256, heads=8, layers=12, mlp=1024, n_ctx=17),
    "vit_h": ModelConfig("vit_h", "vit", d=320, heads=10, layers=14, mlp=1280, n_ctx=17),
    "gpt_s": ModelConfig("gpt_s", "gpt", d=128, heads=4, layers=6, mlp=512, n_ctx=64),
}


def keep_count(dim: int, s10: int) -> int:
    """Kept size of a dimension at sparsity s10/10 (integer arithmetic so
    Python and Rust agree bit-exactly)."""
    assert 0 <= s10 <= 9
    return max(1, (dim * (10 - s10) + 5) // 10)


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------


def block_param_spec(cfg: ModelConfig, dqk: int, o: int) -> List[Tuple[str, Tuple[int, ...]]]:
    """Per-block parameters. dqk = per-head q/k dim (pruned or dense);
    o = MLP hidden dim (pruned or dense). V keeps the dense head dim."""
    d, h, dh = cfg.d, cfg.heads, cfg.dh
    return [
        ("ln1.g", (d,)),
        ("ln1.b", (d,)),
        ("attn.wq", (d, h * dqk)),
        ("attn.bq", (h * dqk,)),
        ("attn.wk", (d, h * dqk)),
        ("attn.bk", (h * dqk,)),
        ("attn.wv", (d, h * dh)),
        ("attn.bv", (h * dh,)),
        ("attn.wo", (h * dh, d)),
        ("attn.bo", (d,)),
        ("ln2.g", (d,)),
        ("ln2.b", (d,)),
        ("mlp.w1", (d, o)),
        ("mlp.b1", (o,)),
        ("mlp.w2", (o, d)),
        ("mlp.b2", (d,)),
    ]


def embed_param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    if cfg.kind == "vit":
        return [
            ("embed.w", (cfg.patch_dim, cfg.d)),
            ("embed.b", (cfg.d,)),
            ("embed.cls", (cfg.d,)),
            ("embed.pos", (cfg.n_ctx, cfg.d)),
        ]
    return [
        ("embed.w", (cfg.vocab, cfg.d)),
        ("embed.pos", (cfg.n_ctx, cfg.d)),
    ]


def head_param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    out = cfg.classes if cfg.kind == "vit" else cfg.vocab
    return [
        ("head.ln.g", (cfg.d,)),
        ("head.ln.b", (cfg.d,)),
        ("head.w", (cfg.d, out)),
        ("head.b", (out,)),
    ]


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical full-model (dense) parameter order."""
    spec = list(embed_param_spec(cfg))
    for layer in range(cfg.layers):
        for name, shape in block_param_spec(cfg, cfg.dh, cfg.mlp):
            spec.append((f"blocks.{layer}.{name}", shape))
    spec.extend(head_param_spec(cfg))
    return spec


# --------------------------------------------------------------------------
# Graph bodies (single example; vmapped over batch at the graph boundary)
# --------------------------------------------------------------------------


def vit_embed_one(tokens, we, be, cls, pos):
    """tokens: [P, pd] -> [P+1, d]."""
    x = tokens @ we + be
    x = jnp.concatenate([cls[None, :], x], axis=0)
    return x + pos


def gpt_embed_one(ids, wemb, pos):
    """ids: [n] int32 -> [n, d] (one-hot matmul keeps the graph gather-free)."""
    onehot = jax.nn.one_hot(ids, wemb.shape[0], dtype=wemb.dtype)
    return onehot @ wemb + pos


def _split_heads(x, h):
    n, hd = x.shape
    return x.reshape(n, h, hd // h).transpose(1, 0, 2)  # [h, n, dh]


def _merge_heads(x):
    h, n, dh = x.shape
    return x.transpose(1, 0, 2).reshape(n, h * dh)


def block_one(x, p, cfg: ModelConfig, causal: bool, use_pallas: bool = True, capture: bool = False):
    """One transformer block on a single example x: [n, d].

    p: dict of per-block params (pruned shapes allowed for wq/wk/w1/w2).
    Returns y, or (y, hidden, Q, K) when capture=True.
    """
    scale = 1.0 / math.sqrt(cfg.dh)  # dense-head scale even when dqk < dh (§3.4)
    h = cfg.heads
    if use_pallas:
        xn = kln.layernorm(x, p["ln1.g"], p["ln1.b"])
    else:
        xn = ref.layernorm(x, p["ln1.g"], p["ln1.b"])
    q = _split_heads(xn @ p["attn.wq"] + p["attn.bq"], h)  # [h, n, dqk]
    k = _split_heads(xn @ p["attn.wk"] + p["attn.bk"], h)
    v = _split_heads(xn @ p["attn.wv"] + p["attn.bv"], h)  # [h, n, dh]
    if use_pallas:
        att = katt.multi_head_attention(q, k, v, scale, causal)
    else:
        att = jnp.stack([ref.attention(q[i], k[i], v[i], scale, causal) for i in range(h)])
    y = x + (_merge_heads(att) @ p["attn.wo"] + p["attn.bo"])
    if use_pallas:
        yn = kln.layernorm(y, p["ln2.g"], p["ln2.b"])
        hidden = kmlp.mlp_hidden(yn, p["mlp.w1"], p["mlp.b1"])
    else:
        yn = ref.layernorm(y, p["ln2.g"], p["ln2.b"])
        hidden = ref.mlp_hidden(yn, p["mlp.w1"], p["mlp.b1"])
    z = y + (hidden @ p["mlp.w2"] + p["mlp.b2"])
    if capture:
        return z, hidden, q, k
    return z


def mlponly_block_one(x, p, use_pallas: bool = True):
    """DC-ViT-like block with the attention module removed."""
    if use_pallas:
        yn = kln.layernorm(x, p["ln2.g"], p["ln2.b"])
        return x + kmlp.mlp(yn, p["mlp.w1"], p["mlp.b1"], p["mlp.w2"], p["mlp.b2"])
    yn = ref.layernorm(x, p["ln2.g"], p["ln2.b"])
    return x + ref.mlp(yn, p["mlp.w1"], p["mlp.b1"], p["mlp.w2"], p["mlp.b2"])


def head_one(x, g, b, w, bias, cfg: ModelConfig, use_pallas: bool = True):
    """Classification / LM head on [n, d]."""
    if use_pallas:
        xn = kln.layernorm(x, g, b)
    else:
        xn = ref.layernorm(x, g, b)
    if cfg.kind == "vit":
        return xn[0] @ w + bias  # CLS token logits [classes]
    return xn @ w + bias  # per-position logits [n, vocab]


def ln_one(x, g, b, use_pallas: bool = True):
    if use_pallas:
        return kln.layernorm(x, g, b)
    return ref.layernorm(x, g, b)


# --------------------------------------------------------------------------
# Full forward + loss (train path: pure-jnp, differentiable)
# --------------------------------------------------------------------------


def _params_to_tree(cfg: ModelConfig, flat: List[jnp.ndarray]):
    """Flat canonical list -> (embed dict, [block dicts], head dict)."""
    spec = param_spec(cfg)
    assert len(flat) == len(spec), (len(flat), len(spec))
    named = dict(zip([n for n, _ in spec], flat))
    embed = {n.split("embed.")[1]: named[n] for n, _ in embed_param_spec(cfg) for n in [n]}
    blocks = []
    for layer in range(cfg.layers):
        blocks.append(
            {n: named[f"blocks.{layer}.{n}"] for n, _ in block_param_spec(cfg, cfg.dh, cfg.mlp)}
        )
    head = {n: named[n] for n, _ in head_param_spec(cfg)}
    return embed, blocks, head


def forward_one(cfg: ModelConfig, flat_params, inp, use_pallas: bool = False):
    """Full dense forward for a single example (train path)."""
    embed, blocks, head = _params_to_tree(cfg, flat_params)
    if cfg.kind == "vit":
        x = vit_embed_one(inp, embed["w"], embed["b"], embed["cls"], embed["pos"])
        causal = False
    else:
        x = gpt_embed_one(inp, embed["w"], embed["pos"])
        causal = True
    for p in blocks:
        x = block_one(x, p, cfg, causal, use_pallas=use_pallas)
    return head_one(x, head["head.ln.g"], head["head.ln.b"], head["head.w"], head["head.b"], cfg, use_pallas=use_pallas)


def loss_fn(cfg: ModelConfig, flat_params, inputs, labels):
    """Mean cross-entropy. vit: labels [B]; gpt: labels [B, n] (next tokens)."""
    logits = jax.vmap(lambda i: forward_one(cfg, flat_params, i))(inputs)
    if cfg.kind == "vit":
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def train_chunk(cfg: ModelConfig, inputs, labels, lrs, t0, flat_params, m_state, v_state):
    """K Adam steps in one graph via lax.scan.

    inputs: [K, B, ...] per-step batches, labels: [K, B, ...], lrs: [K],
    t0: scalar f32 (1-based Adam step counter at chunk start).
    Returns (params', m', v', losses [K]).

    Running K steps per PJRT call keeps parameters and optimizer state on
    device across the chunk — the per-step host↔device round trip of the
    whole parameter set was the dominant training cost (§Perf L3-1).
    """
    n_p = len(flat_params)

    def body(carry, xs):
        params, m, v = carry[:n_p], carry[n_p : 2 * n_p], carry[2 * n_p :]
        inp, lab, lr, i = xs
        new_p, new_m, new_v, loss = train_step(cfg, inp, lab, lr, t0 + i, list(params), list(m), list(v))
        return tuple(new_p) + tuple(new_m) + tuple(new_v), loss

    k = inputs.shape[0]
    carry0 = tuple(flat_params) + tuple(m_state) + tuple(v_state)
    carry, losses = jax.lax.scan(body, carry0, (inputs, labels, lrs, jnp.arange(k, dtype=jnp.float32)))
    return list(carry[:n_p]), list(carry[n_p : 2 * n_p]), list(carry[2 * n_p :]), losses


def train_step(cfg: ModelConfig, inputs, labels, lr, t, flat_params, m_state, v_state):
    """One Adam step (β1=0.9, β2=0.999) with bias correction at step `t`
    (1-based, f32 scalar). Returns (params', m', v', loss).

    SGD+momentum fails to train these transformers on the synthetic task
    (loss plateaus at ln(classes)); Adam is the standard ViT recipe.
    """
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, inputs, labels))(flat_params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_m = [b1 * mi + (1 - b1) * g for mi, g in zip(m_state, grads)]
    new_v = [b2 * vi + (1 - b2) * g * g for vi, g in zip(v_state, grads)]
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    new_params = [
        p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        for p, mi, vi in zip(flat_params, new_m, new_v)
    ]
    return new_params, new_m, new_v, loss
