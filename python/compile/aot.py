"""AOT lowering: JAX graphs -> HLO text artifacts + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Every graph takes its weights as arguments, so the Rust coordinator can run
dense, pruned, and compensated variants from the same artifact family. The
manifest records each artifact's input/output names+shapes in order; the
Rust runtime is entirely manifest-driven.

Usage: python -m compile.aot [--out-dir ../artifacts] [--force] [--only NAME_SUBSTR]
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

TRAIN_CHUNK = 20  # steps per train-chunk call (mirrored in rust train/)
EVAL_B = 16  # evaluation / calibration / throughput-serving batch
LAT_B = 1  # latency-serving batch
GPT_B = 8
SPARSITIES = list(range(0, 8))  # s10 values: 0.0 .. 0.7


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Registry:
    """Collects (name, fn, input specs, output names) graph definitions."""

    def __init__(self):
        self.entries = []

    def add(self, name, fn, inputs, out_names):
        """inputs: list of (name, shape, dtype-str)."""
        self.entries.append({"name": name, "fn": fn, "inputs": inputs, "out_names": out_names})


def block_inputs(cfg, dqk, o, batch):
    ins = [("x", (batch, cfg.n_ctx, cfg.d), "f32")]
    for n, shape in M.block_param_spec(cfg, dqk, o):
        ins.append((n, shape, "f32"))
    return ins


def build_registry() -> Registry:
    reg = Registry()

    for cfg in M.CONFIGS.values():
        causal = cfg.kind == "gpt"
        batches = [GPT_B] if cfg.kind == "gpt" else [EVAL_B, LAT_B]

        # ---- embed ----
        for b in batches:
            if cfg.kind == "vit":
                ins = [("tokens", (b, cfg.patches, cfg.patch_dim), "f32")] + [
                    (n, s, "f32") for n, s in M.embed_param_spec(cfg)
                ]
                fn = lambda tokens, we, be, cls, pos, _c=cfg: (
                    jax.vmap(lambda t: M.vit_embed_one(t, we, be, cls, pos))(tokens),
                )
            else:
                ins = [("ids", (b, cfg.n_ctx), "i32")] + [
                    (n, s, "f32") for n, s in M.embed_param_spec(cfg)
                ]
                fn = lambda ids, wemb, pos, _c=cfg: (
                    jax.vmap(lambda i: M.gpt_embed_one(i, wemb, pos))(ids),
                )
            reg.add(f"embed_{cfg.name}_b{b}", fn, ins, ["x"])

        # ---- head ----
        for b in batches:
            ins = [("x", (b, cfg.n_ctx, cfg.d), "f32")] + [
                (n, s, "f32") for n, s in M.head_param_spec(cfg)
            ]

            def head_fn(x, g, bb, w, bias, _c=cfg):
                return (jax.vmap(lambda xx: M.head_one(xx, g, bb, w, bias, _c))(x),)

            reg.add(f"head_{cfg.name}_b{b}", head_fn, ins, ["logits"])

        # ---- final layernorm (feature extraction for dense tasks) ----
        b0 = batches[0]
        ins = [
            ("x", (b0, cfg.n_ctx, cfg.d), "f32"),
            ("g", (cfg.d,), "f32"),
            ("b", (cfg.d,), "f32"),
        ]
        reg.add(
            f"lnf_{cfg.name}_b{b0}",
            lambda x, g, b: (jax.vmap(lambda xx: M.ln_one(xx, g, b))(x),),
            ins,
            ["features"],
        )

        # ---- capture block (dense shapes; calibration pass) ----
        def cap_fn(x, *params, _c=cfg, _causal=causal):
            names = [n for n, _ in M.block_param_spec(_c, _c.dh, _c.mlp)]

            def one(xx):
                p = dict(zip(names, params))
                return M.block_one(xx, p, _c, _causal, capture=True)

            y, hidden, q, k = jax.vmap(one)(x)
            return (y, hidden, q, k)

        reg.add(
            f"blockcap_{cfg.name}_b{b0}",
            cap_fn,
            block_inputs(cfg, cfg.dh, cfg.mlp, b0),
            ["y", "hidden", "q", "k"],
        )

        # ---- block variants ----
        if cfg.kind == "vit":
            shape_set = {(cfg.dh, cfg.mlp)}
            for s in SPARSITIES[1:]:
                shape_set.add((M.keep_count(cfg.dh, s), cfg.mlp))
                shape_set.add((cfg.dh, M.keep_count(cfg.mlp, s)))
                shape_set.add((M.keep_count(cfg.dh, s), M.keep_count(cfg.mlp, s)))
            joint_set = {(cfg.dh, cfg.mlp)} | {
                (M.keep_count(cfg.dh, s), M.keep_count(cfg.mlp, s)) for s in SPARSITIES[1:]
            }
        else:
            s = 3  # OPT experiment: 30% sparsity
            shape_set = {
                (cfg.dh, cfg.mlp),
                (M.keep_count(cfg.dh, s), cfg.mlp),
                (cfg.dh, M.keep_count(cfg.mlp, s)),
                (M.keep_count(cfg.dh, s), M.keep_count(cfg.mlp, s)),
            }
            joint_set = set()

        def make_block_fn(dqk, o, _c=cfg, _causal=causal):
            names = [n for n, _ in M.block_param_spec(_c, dqk, o)]

            def fn(x, *params):
                def one(xx):
                    return M.block_one(xx, dict(zip(names, params)), _c, _causal)

                return (jax.vmap(one)(x),)

            return fn

        for dqk, o in sorted(shape_set):
            reg.add(
                f"block_{cfg.name}_q{dqk}_o{o}_b{b0}",
                make_block_fn(dqk, o),
                block_inputs(cfg, dqk, o, b0),
                ["y"],
            )
        if cfg.kind == "vit":
            for dqk, o in sorted(joint_set):
                reg.add(
                    f"block_{cfg.name}_q{dqk}_o{o}_b{LAT_B}",
                    make_block_fn(dqk, o),
                    block_inputs(cfg, dqk, o, LAT_B),
                    ["y"],
                )

        # ---- train step ----
        tb = GPT_B if cfg.kind == "gpt" else EVAL_B
        spec = M.param_spec(cfg)
        if cfg.kind == "vit":
            data_ins = [
                ("tokens", (tb, cfg.patches, cfg.patch_dim), "f32"),
                ("labels", (tb,), "i32"),
            ]
        else:
            data_ins = [("ids", (tb, cfg.n_ctx), "i32"), ("labels", (tb, cfg.n_ctx), "i32")]
        # Chunked training: K steps per call, data for all K steps as one
        # input slab (keeps params/optimizer state on device; §Perf L3-1).
        k = TRAIN_CHUNK
        chunk_data = [(n, (k, *s), d) for n, s, d in data_ins]
        train_ins = chunk_data + [("lrs", (k,), "f32"), ("t0", (), "f32")]
        train_ins += [(n, s, "f32") for n, s in spec]
        train_ins += [(f"adam_m.{n}", s, "f32") for n, s in spec]
        train_ins += [(f"adam_v.{n}", s, "f32") for n, s in spec]
        n_params = len(spec)

        def train_fn(inputs, labels, lrs, t0, *rest, _c=cfg, _n=n_params):
            params = list(rest[:_n])
            m_state = list(rest[_n : 2 * _n])
            v_state = list(rest[2 * _n :])
            new_p, new_m, new_v, losses = M.train_chunk(_c, inputs, labels, lrs, t0, params, m_state, v_state)
            return tuple(new_p) + tuple(new_m) + tuple(new_v) + (losses,)

        out_names = (
            [n for n, _ in spec]
            + [f"adam_m.{n}" for n, _ in spec]
            + [f"adam_v.{n}" for n, _ in spec]
            + ["losses"]
        )
        reg.add(f"train_{cfg.name}", train_fn, train_ins, out_names)

        # ---- eval loss graph (gpt perplexity / vit val loss) ----
        def evloss_fn(inputs, labels, *params, _c=cfg):
            return (M.loss_fn(_c, list(params), inputs, labels),)

        reg.add(
            f"evloss_{cfg.name}",
            evloss_fn,
            data_ins + [(n, s, "f32") for n, s in spec],
            ["loss"],
        )

    # ---- DC-ViT-like attention-free blocks (vit_b only, pruned MLP grid) ----
    cfg = M.CONFIGS["vit_b"]
    for s in SPARSITIES:
        o = M.keep_count(cfg.mlp, s) if s > 0 else cfg.mlp
        names = [n for n, _ in M.block_param_spec(cfg, cfg.dh, o)]
        mlp_names = ["ln2.g", "ln2.b", "mlp.w1", "mlp.b1", "mlp.w2", "mlp.b2"]
        ins = [("x", (EVAL_B, cfg.n_ctx, cfg.d), "f32")] + [
            (n, s2, "f32") for n, s2 in M.block_param_spec(cfg, cfg.dh, o) if n in mlp_names
        ]

        def mlponly_fn(x, g, b, w1, b1, w2, b2):
            p = {"ln2.g": g, "ln2.b": b, "mlp.w1": w1, "mlp.b1": b1, "mlp.w2": w2, "mlp.b2": b2}
            return (jax.vmap(lambda xx: M.mlponly_block_one(xx, p))(x),)

        _ = names
        reg.add(f"mlponly_{cfg.name}_o{o}_b{EVAL_B}", mlponly_fn, ins, ["y"])

    return reg


DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def lower_entry(entry, out_dir: Path, force: bool) -> dict:
    path = out_dir / f"{entry['name']}.hlo.txt"
    meta = {
        "name": entry["name"],
        "file": path.name,
        "inputs": [
            {"name": n, "shape": list(s), "dtype": d} for n, s, d in entry["inputs"]
        ],
        "outputs": entry["out_names"],
    }
    if path.exists() and not force:
        return meta
    args = [_sds(s, DTYPES[d]) for _, s, d in entry["inputs"]]
    t0 = time.time()
    lowered = jax.jit(entry["fn"]).lower(*args)
    text = to_hlo_text(lowered)
    path.write_text(text)
    print(f"  {entry['name']}: {len(text) / 1e6:.2f} MB in {time.time() - t0:.1f}s", flush=True)
    return meta


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=str(Path(__file__).resolve().parents[2] / "artifacts"))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="", help="substring filter on artifact names")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    reg = build_registry()
    manifest = []
    t0 = time.time()
    for entry in reg.entries:
        # --only limits which artifacts get (re)lowered, but the manifest
        # always describes every artifact whose HLO file is present.
        skip = bool(args.only) and args.only not in entry["name"]
        if skip and not (out_dir / f"{entry['name']}.hlo.txt").exists():
            continue
        meta = lower_entry(entry, out_dir, args.force and not skip)
        manifest.append(meta)
    (out_dir / "manifest.json").write_text(json.dumps({"artifacts": manifest}, indent=1))
    print(f"{len(manifest)} artifacts ready in {time.time() - t0:.1f}s -> {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
