#!/usr/bin/env bash
# CI entrypoint: format check (advisory), clippy, tier-1 build+test, rustdoc
# (deny warnings), the NaN-safe-ordering grep gate, and the perf harnesses
# (BENCH_linalg.json + smoke runs of the serving and pruning harnesses
# emitting BENCH_serve.json / BENCH_prune.json at the repo root).
#
# Usage: scripts/check.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

MANIFEST=rust/Cargo.toml

echo "==> cargo fmt --check (advisory)"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --manifest-path "$MANIFEST" --check || \
        echo "warn: rustfmt differences (not failing the build)"
else
    echo "warn: rustfmt not installed; skipping"
fi

echo "==> cargo clippy"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --manifest-path "$MANIFEST" --release
else
    echo "warn: clippy not installed; skipping"
fi

echo "==> cargo build --release"
cargo build --manifest-path "$MANIFEST" --release

echo "==> cargo test -q"
cargo test --manifest-path "$MANIFEST" -q

# The kernel suites run twice: once under runtime dispatch (AVX2 where
# the host has it) above, and once with CORP_SIMD=off forcing the
# portable tile — the dispatch ladder promises bitwise-identical results
# on both rungs, so the same tests must pass on each.
echo "==> cargo test -q --lib linalg (CORP_SIMD=off, forced portable tile)"
CORP_SIMD=off cargo test --manifest-path "$MANIFEST" -q --lib linalg

echo "==> cargo doc --no-deps (deny warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --manifest-path "$MANIFEST" --no-deps --quiet

# NaN-safety gate: float-key orderings must use total_cmp or the
# rank::nan_last_desc comparator. A same-line `partial_cmp(..)` +
# `.unwrap()` in non-test source reintroduces the panic-on-NaN sorts
# this gate exists to keep out (test code under rust/tests/ is exempt;
# #[cfg(test)] modules inside src still trip it, deliberately).
echo "==> grep gate: no partial_cmp(..).unwrap() orderings in rust/src"
if grep -rn --include='*.rs' 'partial_cmp(.*)\.unwrap()' rust/src/; then
    echo "error: NaN-unsafe float ordering (use total_cmp or rank::nan_last_desc)" >&2
    exit 1
fi

# Poison-safety gate: serving and execution code must take mutexes through
# the util::lock helpers (which recover the data from a poisoned lock after
# an absorbed worker panic) — a bare `.lock().unwrap()` / `.read().unwrap()`
# / `.write().unwrap()` there would turn one supervised panic into a
# cascade of poison panics on every other thread.
echo "==> grep gate: no bare .lock()/.read()/.write().unwrap() in rust/src/serve + rust/src/exec"
if grep -rn --include='*.rs' -E '\.(lock|read|write)\(\)\s*\.unwrap\(\)' rust/src/serve/ rust/src/exec/; then
    echo "error: poison-unsafe mutex access (use crate::util::lock::{lock,read,write})" >&2
    exit 1
fi

if [[ "${1:-}" != "--no-bench" ]]; then
    # corp-bench-linalg/v2: every kernel cell times the full dispatch
    # ladder (runtime-selected SIMD tile, forced-portable via
    # CORP_SIMD=off, seed scalar baseline) and the quantized section
    # benches the int8 gemm_q8 cell against f32 — so this one run covers
    # the int8 row the quantized serving path rides on. A failed cell
    # exits non-zero with its grid coordinates and leaves no stale
    # BENCH_linalg.json behind.
    echo "==> bench linalg (CORP_BENCH_MODE=${CORP_BENCH_MODE:-fast})"
    cargo run --manifest-path "$MANIFEST" --release -- bench linalg --json --out BENCH_linalg.json

    # The smoke grid sweeps all three workloads (vision + text + gen, the
    # gen cells on kv, kv+chunked/shared-prefix, and prefill decode) and
    # both dispatch policies — corp-bench-serve/v7 axes with the paged-KV
    # telemetry columns, the load-spike controller cell (controller
    # off vs on, measured cost tables through the deterministic
    # simulator), the compensated_int8 variant rows (the
    # pruned+compensated store weight-quantized to int8, served through
    # run_engine_q8), and the chaos cell (seeded kill/fail/delay plan
    # through the simulator with the fault-rate degrade signal armed,
    # reporting failures/retries/timeouts/respawns/reclaims and goodput).
    # A failed cell exits non-zero and leaves no stale BENCH_serve.json
    # behind.
    echo "==> bench serve smoke (CORP_BENCH_MODE=smoke)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- bench serve --json --out BENCH_serve.json

    # corp-bench-prune/v1: the criterion zoo (combined + variance + obs +
    # energy) × the smoke sparsity grid, each cell scored compensated
    # (CORP) and uncompensated (naive), plus the global FLOPs allocator
    # cells (achieved-vs-requested budget, per-layer keep vectors). A
    # failed cell exits non-zero and leaves no stale BENCH_prune.json.
    echo "==> bench prune smoke (CORP_BENCH_MODE=smoke)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- bench prune --json --out BENCH_prune.json

    # Allocator CLI smoke: one global FLOPs budget on vit_t end to end —
    # calibrate, greedy-allocate per-layer keeps, prune with compensation
    # on the non-uniform shapes, report achieved FLOPs from the actual
    # pruned store.
    echo "==> prune CLI smoke (criterion zoo + --flops-budget)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        prune --model vit_t --criterion obs --sparsity 0.5 --calib 2
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        prune --model vit_t --criterion energy --flops-budget 60 --calib 2

    echo "==> serve CLI smoke (vision/exact + text/padded + gen)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        serve --model vit_t --sparsity 0.5 --requests 32 --rate 0 --max-batch 8 --dispatch exact
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        serve --model gpt_s --sparsity 0 --requests 16 --rate 0 --max-batch 4 --dispatch padded
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        serve --model gpt_s --workload gen --sparsity 0 --requests 12 --rate 0 --max-batch 4 --max-new 4

    # Controller smoke: a 3× load spike over the middle third of the
    # schedule with the SLO feedback controller on and variant
    # degradation armed (dense primary + compensated fallback at 50%
    # sparsity) — exercises the threaded control loop, the adaptive
    # dispatch threshold, and the controller summary line end to end.
    echo "==> serve CLI smoke (controller + degrade, 3x load spike)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        serve --model vit_t --sparsity 0.5 --workload vision --requests 48 --rate 300 --spike 3 \
        --workers 1 --max-batch 8 --queue-cap 16 --exec-floor 0.01 \
        --controller --degrade --slo-p99-ms 500

    # Chaos smoke: the fault-tolerant serving path end to end — an
    # injected worker kill, two dispatch faults, and a delay against a
    # retry budget of 2. The CLI exits non-zero on a process abort, an
    # unsupervised worker death, or leaked KV blocks (the post-run
    # `blocks_in_use == registered_blocks` check), so a zero exit here IS
    # the assertion.
    echo "==> serve CLI smoke (chaos: kill + fail + delay, retries)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        serve --model vit_t --sparsity 0 --requests 32 --rate 0 --max-batch 8 --workers 2 \
        --chaos kill=0@1,fail=3,fail=7@0,delay=5:10 --retries 2 --request-timeout-ms 60000

    # Int8 smoke: the quantized serving path end to end. First serve the
    # int8 store directly (run_engine_q8 — per-channel scales with the
    # compensation-folded dequant correction fitted from the calibration
    # stats), then re-run the controller spike with --quantize appending
    # the int8 store as the cheapest rung of the degrade ladder
    # (dense -> pruned+compensated -> int8).
    echo "==> serve CLI smoke (int8 direct + int8 degrade rung)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        serve --model vit_t --sparsity 0.5 --quantize --requests 32 --rate 0 --max-batch 8
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        serve --model vit_t --sparsity 0.5 --workload vision --requests 48 --rate 300 --spike 3 \
        --workers 1 --max-batch 8 --queue-cap 16 --exec-floor 0.01 \
        --controller --degrade --quantize --slo-p99-ms 500

    # Paged-KV smoke: same gen workload with prefills chunked to 8 tokens
    # and a 16-token shared prompt opening — exercises chunked prefill
    # interleaving, prefix-block adoption, and the kv pool summary line.
    echo "==> serve CLI smoke (gen, chunked prefill + shared prefix)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        serve --model gpt_s --workload gen --sparsity 0 --requests 12 --rate 0 --max-batch 4 --max-new 4 \
        --prefill-chunk 8 --shared-prefix 16

    # Generation smoke: 8 greedy tokens on gpt_s, KV-cache decode (prompts
    # prefilled in 4-token chunks) cross-checked against one-shot kv,
    # prefill-per-step, and the fused full forward (checksum/logit
    # compare; non-zero exit on any drift).
    echo "==> generate smoke (gpt_s, 8 tokens, chunked kv vs prefill verify)"
    CORP_BENCH_MODE=smoke cargo run --manifest-path "$MANIFEST" --release -- \
        generate --model gpt_s --sparsity 0.5 --tokens 8 --prompts 2 --decode kv --prefill-chunk 4 --verify
fi

echo "ok"
