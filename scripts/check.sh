#!/usr/bin/env bash
# CI entrypoint: format check (advisory), clippy, tier-1 build+test, and the
# linalg perf harness (emits BENCH_linalg.json at the repo root).
#
# Usage: scripts/check.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

MANIFEST=rust/Cargo.toml

echo "==> cargo fmt --check (advisory)"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --manifest-path "$MANIFEST" --check || \
        echo "warn: rustfmt differences (not failing the build)"
else
    echo "warn: rustfmt not installed; skipping"
fi

echo "==> cargo clippy"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --manifest-path "$MANIFEST" --release
else
    echo "warn: clippy not installed; skipping"
fi

echo "==> cargo build --release"
cargo build --manifest-path "$MANIFEST" --release

echo "==> cargo test -q"
cargo test --manifest-path "$MANIFEST" -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "==> bench linalg (CORP_BENCH_MODE=${CORP_BENCH_MODE:-fast})"
    cargo run --manifest-path "$MANIFEST" --release -- bench linalg --json --out BENCH_linalg.json
fi

echo "ok"
