//! Quickstart: prune a small ViT with CORP in one calibration pass.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads (or trains, first run) the vit_t checkpoint, runs the CORP pipeline
//! at 50% joint sparsity, and compares dense vs pruned vs uncompensated
//! accuracy — the paper's core claim in ~30 lines of user code.

use corp::coordinator::Coordinator;
use corp::model::{ModelConfig, Scope, Sparsity};
use corp::prune::{Method, PruneOpts};

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new()?;
    let cfg = ModelConfig::by_name("vit_t").unwrap();

    // 1. A "pretrained" dense model (trained on first use, then cached).
    let dense = coord.dense(cfg)?.clone();
    let dense_acc = coord.top1(cfg, &dense, 99)?;
    println!("dense {}: top-1 {dense_acc:.2}%  ({} params)", cfg.name, dense.param_count());

    // 2. One-shot CORP pruning at 50% joint sparsity: unlabeled calibration,
    //    closed-form compensation, weights folded — no gradients anywhere.
    let opts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        calib_batches: coord.scale.calib_batches,
        ..PruneOpts::default()
    };
    let corp = coord.prune_job(cfg, &opts)?;
    let corp_acc = coord.top1(cfg, &corp.weights, 99)?;

    // 3. The ablation: same ranking, no compensation.
    let naive_opts = PruneOpts { method: Method::Naive, ..opts };
    let naive = coord.prune_job(cfg, &naive_opts)?;
    let naive_acc = coord.top1(cfg, &naive.weights, 99)?;

    println!("CORP  @50% joint: top-1 {corp_acc:.2}%  (mean MLP rho2 {:.3})", corp.mean_mlp_rho2);
    println!("naive @50% joint: top-1 {naive_acc:.2}%");
    println!(
        "compensation recovers {:+.2} accuracy points over naive pruning",
        corp_acc - naive_acc
    );
    Ok(())
}
