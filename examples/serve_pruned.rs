//! Serving scenario: the dynamic batcher over a CORP-pruned model.
//!
//! An open-loop Poisson arrival stream feeds the engine; requests are
//! batched greedily with a wait bound and executed through PJRT. Compares
//! dense vs pruned under the same load — the deployment story behind the
//! paper's Table 5 throughput column.
//!
//! ```text
//! cargo run --release --example serve_pruned -- --model vit_s --rate 120
//! ```

use corp::coordinator::Coordinator;
use corp::data::VisionGen;
use corp::model::{ModelConfig, Scope, Sparsity};
use corp::prune::PruneOpts;
use corp::serve::{run_batcher, BatcherOpts};
use corp::util::cli::Command;

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("serve_pruned", "dynamic batcher demo")
        .opt("model", "model name", "vit_s")
        .opt("rate", "arrival rate, req/s", "120")
        .opt("requests", "total requests", "192")
        .opt("sparsity", "joint sparsity", "0.5");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cmd.parse(&argv).map_err(|e| anyhow::anyhow!("{e}\n{}", cmd.usage()))?;

    let mut coord = Coordinator::new()?;
    let cfg = ModelConfig::by_name(&args.str("model"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let s10 = (args.f64("sparsity")? * 10.0).round() as u8;

    let dense = coord.dense(cfg)?.clone();
    let pruned = coord
        .prune_job(cfg, &PruneOpts {
            sparsity: Sparsity::of(Scope::Both, s10),
            calib_batches: coord.scale.calib_batches,
            ..PruneOpts::default()
        })?
        .weights;

    let exec = coord.executor(cfg);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let bopts = BatcherOpts {
        rate: args.f64("rate")?,
        requests: args.usize("requests")?,
        ..Default::default()
    };
    println!("load: {} req at {:.0}/s, max batch {}, max wait {:.0}ms", bopts.requests, bopts.rate, bopts.max_batch, bopts.max_wait * 1e3);
    for (label, w) in [("dense", &dense), ("pruned", &pruned)] {
        let s = run_batcher(&exec, w, &gen, &bopts)?;
        println!(
            "{label:7}: served {} | p50 {:.1}ms p95 {:.1}ms | mean batch {:.1} | {:.0} req/s",
            s.served, s.p50_ms, s.p95_ms, s.mean_batch, s.throughput_fps
        );
    }
    Ok(())
}
