//! Serving scenario: the concurrent batched engine over a CORP-pruned model.
//!
//! An open-loop Poisson arrival stream feeds a bounded queue drained by a
//! pool of worker threads; batches form up to `--max-batch` under a
//! batching deadline and dispatch through the batch-polymorphic pruned-shape
//! fast path — padded to the artifact batch, at their exact size, or `auto`
//! (exact below half fill) per `--dispatch`. Compares dense vs pruned vs
//! compensated under the same offered load and worker count — the
//! deployment story behind the paper's Table 5 throughput column.
//!
//! ```text
//! cargo run --release --example serve_pruned -- --model vit_s --rate 120 --dispatch exact
//! ```

use corp::coordinator::Coordinator;
use corp::model::{ModelConfig, Scope, Sparsity};
use corp::prune::{Method, PruneOpts};
use corp::serve::{run_engine, DispatchPolicy, EngineOpts, VisionWorkload};
use corp::util::cli::Command;

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("serve_pruned", "concurrent serving engine demo")
        .opt("model", "model name", "vit_s")
        .opt("rate", "arrival rate, req/s (0 = saturated)", "120")
        .opt("requests", "total requests", "192")
        .opt("sparsity", "joint sparsity", "0.5")
        .opt("workers", "engine worker threads", "2")
        .opt("max-batch", "max requests per batch", "16")
        .opt("dispatch", "batch dispatch shape: padded|exact|auto", "auto")
        .opt("seed", "arrival-process seed", "7");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cmd.parse(&argv).map_err(|e| anyhow::anyhow!("{e}\n{}", cmd.usage()))?;

    let mut coord = Coordinator::new()?;
    let cfg = ModelConfig::by_name(&args.str("model"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let s10 = (args.f64("sparsity")? * 10.0).round() as u8;

    let dense = coord.dense(cfg)?.clone();
    let base = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, s10),
        calib_batches: coord.scale.calib_batches,
        ..PruneOpts::default()
    };
    let pruned = coord
        .prune_job(cfg, &PruneOpts { method: Method::Naive, ..base.clone() })?
        .weights;
    let comp = coord.prune_job(cfg, &base)?.weights;

    let exec = coord.executor(cfg);
    let workload = VisionWorkload::new(cfg, corp::data::DATA_SEED)?;
    let eopts = EngineOpts {
        workers: args.usize("workers")?,
        rate: args.f64("rate")?,
        requests: args.usize("requests")?,
        max_batch: args.usize("max-batch")?,
        seed: args.usize("seed")? as u64,
        dispatch: DispatchPolicy::parse(&args.str("dispatch"))?,
        ..Default::default()
    };
    println!(
        "load: {} req at {:.0}/s, {} worker(s), max batch {}, deadline {:.0}ms, dispatch {}",
        eopts.requests,
        eopts.rate,
        eopts.workers,
        eopts.max_batch,
        eopts.max_wait * 1e3,
        eopts.dispatch.label()
    );
    for (label, w) in [("dense", &dense), ("pruned", &pruned), ("compensated", &comp)] {
        let s = run_engine(&exec, w, &workload, &eopts)?;
        println!(
            "{label:12}: served {} ({} shed) | p50 {:.1}ms p95 {:.1}ms (queue p50 {:.1}ms) | \
             batch {:.1} → dispatch {:.1} | {:.0} images/sec",
            s.served,
            s.shed,
            s.p50_ms,
            s.p95_ms,
            s.queue_p50_ms,
            s.mean_batch,
            s.mean_dispatch,
            s.throughput_fps
        );
    }
    Ok(())
}
