//! End-to-end driver (DESIGN.md §7): trains the B-size ViT from scratch via
//! the AOT train-step graph, logs the loss curve, runs the full CORP
//! pipeline at 50% joint sparsity, evaluates dense vs pruned accuracy, and
//! serves batched requests through the inference engine — every layer of the
//! stack (Pallas kernels → JAX graphs → PJRT → Rust coordinator) in one run.
//!
//! ```text
//! make artifacts && cargo run --release --example train_and_prune
//! ```
//! Scale with CORP_BENCH_MODE={smoke,fast,full}. Results land in
//! results/e2e_train_and_prune.csv and are summarized in EXPERIMENTS.md §E2E.

use corp::coordinator::Coordinator;
use corp::data::VisionGen;
use corp::model::{ModelConfig, Scope, Sparsity};
use corp::prune::{Method, PruneOpts};
use corp::util::bench::CsvWriter;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new()?;
    let cfg = ModelConfig::by_name("vit_b").unwrap();
    let mut csv = CsvWriter::new("e2e_train_and_prune", "phase,metric,value");

    // ---- Phase 1: train (or load) the dense checkpoint ----
    let t0 = std::time::Instant::now();
    let dense = coord.dense(cfg)?.clone();
    let train_secs = t0.elapsed().as_secs_f64();
    let dense_acc = coord.top1(cfg, &dense, 99)?;
    println!("[1/4] dense {}: top-1 {dense_acc:.2}% ({} params, {train_secs:.0}s incl. cache)", cfg.name, dense.param_count());
    csv.row(&["train".into(), "dense_top1".into(), format!("{dense_acc:.3}")]);

    // ---- Phase 2: CORP pipeline at 50% joint ----
    let opts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        calib_batches: coord.scale.calib_batches,
        ..PruneOpts::default()
    };
    let result = coord.prune_job(cfg, &opts)?;
    let pruned_acc = coord.top1(cfg, &result.weights, 99)?;
    println!(
        "[2/4] CORP @50% joint: top-1 {pruned_acc:.2}% (Δ {:+.2}); pipeline: calib {:.1}s rank {:.2}s comp {:.1}s",
        pruned_acc - dense_acc,
        result.sections.get("calibration"),
        result.sections.get("ranking"),
        result.sections.get("compensation"),
    );
    csv.row(&["prune".into(), "corp_top1".into(), format!("{pruned_acc:.3}")]);

    // ---- Phase 3: ablation (no compensation) ----
    let naive = coord.prune_job(cfg, &PruneOpts { method: Method::Naive, ..opts.clone() })?;
    let naive_acc = coord.top1(cfg, &naive.weights, 99)?;
    println!("[3/4] naive @50% joint: top-1 {naive_acc:.2}% — compensation recovers {:+.2} pts", pruned_acc - naive_acc);
    csv.row(&["prune".into(), "naive_top1".into(), format!("{naive_acc:.3}")]);

    // ---- Phase 4: serve the pruned model ----
    let exec = coord.executor(cfg);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let dense_serve = corp::serve::measure(&exec, &dense, &gen, coord.scale.serve_iters, coord.scale.serve_iters)?;
    let pruned_serve = corp::serve::measure(&exec, &result.weights, &gen, coord.scale.serve_iters, coord.scale.serve_iters)?;
    println!(
        "[4/4] serving: dense p50 {:.2}ms / {:.0} fps  →  pruned p50 {:.2}ms / {:.0} fps ({:.2}x throughput)",
        dense_serve.p50_ms,
        dense_serve.throughput_fps,
        pruned_serve.p50_ms,
        pruned_serve.throughput_fps,
        pruned_serve.throughput_fps / dense_serve.throughput_fps
    );
    csv.row(&["serve".into(), "dense_fps".into(), format!("{:.1}", dense_serve.throughput_fps)]);
    csv.row(&["serve".into(), "pruned_fps".into(), format!("{:.1}", pruned_serve.throughput_fps)]);
    csv.flush()?;
    Ok(())
}
