//! Sparsity sweep (the Figure 2 shape, as a user-facing example): accuracy
//! vs sparsity for CORP and the no-compensation ablation on one model.
//!
//! ```text
//! cargo run --release --example sparsity_sweep -- --model vit_s --scope both
//! ```

use corp::coordinator::Coordinator;
use corp::model::{ModelConfig, Scope, Sparsity};
use corp::prune::{Method, PruneOpts};
use corp::util::cli::Command;

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("sparsity_sweep", "accuracy vs sparsity")
        .opt("model", "model name", "vit_s")
        .opt("scope", "mlp|attn|both", "both");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cmd.parse(&argv).map_err(|e| anyhow::anyhow!("{e}\n{}", cmd.usage()))?;
    let scope = match args.str("scope").as_str() {
        "mlp" => Scope::Mlp,
        "attn" => Scope::Attn,
        _ => Scope::Both,
    };

    let mut coord = Coordinator::new()?;
    let cfg = ModelConfig::by_name(&args.str("model"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };

    let dense = coord.dense(cfg)?.clone();
    let dense_acc = coord.top1(cfg, &dense, 99)?;
    println!("{} {} sweep (dense {dense_acc:.2}%)", cfg.name, scope.label());
    println!("{:>8} | {:>8} | {:>8} | {:>7}", "sparsity", "CORP", "naive", "gap");
    for s in [2u8, 4, 5, 6, 7] {
        let sp = Sparsity::of(scope, s);
        let (corp_acc, _, _, _) = coord.accuracy_at(cfg, sp, Method::Corp, &opts)?;
        let (naive_acc, _, _, _) = coord.accuracy_at(cfg, sp, Method::Naive, &opts)?;
        println!(
            "{:8.1} | {corp_acc:8.2} | {naive_acc:8.2} | {:+7.2}",
            s as f64 / 10.0,
            corp_acc - naive_acc
        );
    }
    Ok(())
}
